//! Model graph execution: `(params…, x, y) → (loss, grads…)` and
//! `(params…, x) → logits`, over flat parameter vectors.

use super::{literal_f32, literal_i32, Graph, Runtime};
use crate::data::{Batch, Dataset};
use crate::models::{Manifest, ModelMeta, ParamLayout};
use anyhow::{anyhow, Result};
use std::path::Path;
use std::sync::Arc;

pub struct ModelRuntime {
    pub meta: ModelMeta,
    pub layout: ParamLayout,
    grad: Graph,
    eval: Graph,
}

impl ModelRuntime {
    pub fn load(rt: &Arc<Runtime>, artifacts: &Path, manifest: &Manifest, model: &str) -> Result<Self> {
        let meta = manifest.model(model)?.clone();
        let grad = rt.load(&artifacts.join(&meta.grad_artifact))?;
        let eval = rt.load(&artifacts.join(&meta.eval_artifact))?;
        let layout = ParamLayout::from_meta(&meta);
        Ok(Self { meta, layout, grad, eval })
    }

    pub fn dim(&self) -> usize {
        self.layout.total()
    }

    fn param_literals(&self, flat: &[f32]) -> Result<Vec<xla::Literal>> {
        (0..self.layout.nparams())
            .map(|i| literal_f32(self.layout.slice(flat, i), &self.layout.shapes[i]))
            .collect()
    }

    /// Run the fwd/bwd graph at `flat` weights on `batch`.
    /// Returns (loss, flat gradient).
    pub fn loss_grad(&self, flat: &[f32], batch: &Batch) -> Result<(f32, Vec<f32>)> {
        assert_eq!(flat.len(), self.dim());
        let mut inputs = self.param_literals(flat)?;
        match batch {
            Batch::Vision { x, y } => {
                inputs.push(literal_f32(x, &self.meta.train_x.shape)?);
                inputs.push(literal_i32(y, &self.meta.train_y.shape)?);
            }
            Batch::Text { x, y } => {
                inputs.push(literal_i32(x, &self.meta.train_x.shape)?);
                inputs.push(literal_i32(y, &self.meta.train_y.shape)?);
            }
        }
        let outs = self.grad.run(&inputs)?;
        if outs.len() != 1 + self.layout.nparams() {
            return Err(anyhow!("grad graph returned {} outputs, want {}", outs.len(), 1 + self.layout.nparams()));
        }
        let loss = outs[0].get_first_element::<f32>()?;
        let mut gflat = vec![0.0f32; self.dim()];
        for (i, lit) in outs[1..].iter().enumerate() {
            let dst = self.layout.slice_mut(&mut gflat, i);
            lit.copy_raw_to(dst)?;
        }
        Ok((loss, gflat))
    }

    /// Logits for an eval batch (x only); returns the flat logits buffer.
    pub fn logits(&self, flat: &[f32], batch: &Batch) -> Result<Vec<f32>> {
        let mut inputs = self.param_literals(flat)?;
        match batch {
            Batch::Vision { x, .. } => inputs.push(literal_f32(x, &self.meta.eval_x.shape)?),
            Batch::Text { x, .. } => inputs.push(literal_i32(x, &self.meta.eval_x.shape)?),
        }
        let outs = self.eval.run(&inputs)?;
        Ok(outs[0].to_vec::<f32>()?)
    }

    /// Top-1 accuracy over `nbatches` deterministic eval batches.
    /// For LM models this is next-token accuracy over all positions.
    pub fn accuracy(&self, flat: &[f32], data: &dyn Dataset, nbatches: usize) -> Result<f32> {
        let eval_b = self.meta.eval_x.shape[0];
        let ncls = self.meta.num_classes;
        let mut correct = 0usize;
        let mut total = 0usize;
        let nb = nbatches.min(data.eval_batches(eval_b)).max(1);
        for bi in 0..nb {
            let batch = data.eval_batch(bi, eval_b);
            let logits = self.logits(flat, &batch)?;
            let labels = batch.labels();
            let rows = logits.len() / ncls;
            debug_assert_eq!(rows, labels.len());
            for r in 0..rows {
                let row = &logits[r * ncls..(r + 1) * ncls];
                let mut best = 0usize;
                for c in 1..ncls {
                    if row[c] > row[best] {
                        best = c;
                    }
                }
                if best as i32 == labels[r] {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok(correct as f32 / total.max(1) as f32)
    }

    /// Deterministic parameter init matching `ModelSpec.init` on the
    /// python side *in distribution* (not bit-identical — init lives on
    /// the Rust side at run time; the python init is only used by the
    /// python tests).
    pub fn init_flat(&self, seed: u64) -> Vec<f32> {
        let mut flat = vec![0.0f32; self.dim()];
        let mut rng = crate::quant::seeded_rng(seed, 77);
                for i in 0..self.layout.nparams() {
            let name = self.layout.names[i].clone();
            let shape = self.layout.shapes[i].clone();
            let fan_in: usize = shape[..shape.len().saturating_sub(1)].iter().product::<usize>().max(1);
            let dst = self.layout.slice_mut(&mut flat, i);
            if name.ends_with("_b") || name.contains("_bias") {
                // zeros
            } else if name.contains("_scale") {
                dst.fill(1.0);
            } else {
                let std = if name.contains("emb") { 0.02 } else { (2.0 / fan_in as f32).sqrt() };
                for d in dst.iter_mut() {
                    // Irwin-Hall(12) ~ N(0,1)
                    let n: f32 = (0..12).map(|_| rng.gen_f32()).sum::<f32>() - 6.0;
                    *d = std * n;
                }
            }
        }
        flat
    }
}
