//! The Pallas-backed fused QAdam step (L1 kernel, executed via PJRT).
//!
//! `qadam_step.hlo.txt` operates on one flat f32 chunk (default 64Ki):
//! `(m, v, g, e, alpha, beta, theta, eps, qlo) → (m1, v1, qdelta, e1)`.
//! This type loops the compiled kernel over the parameter vector in
//! chunk-sized pieces (padding the tail with zeros — zeros are a fixed
//! point of the whole chain, so padding is inert) and stitches results
//! back into the caller's buffers.
//!
//! The quantization scale is per-chunk (`max|u|` of that chunk), which
//! matches `python/compile/kernels/qadam.py` and DESIGN.md.

use super::{literal_f32, literal_scalar, Graph, Runtime};
use crate::models::Manifest;
use anyhow::Result;
use std::path::Path;
use std::sync::Arc;

pub struct KernelQAdam {
    graph: Graph,
    pub chunk: usize,
}

/// Scalar hyperparameters of one step.
#[derive(Clone, Copy, Debug)]
pub struct StepScalars {
    pub alpha: f32,
    pub beta: f32,
    pub theta: f32,
    pub eps: f32,
    /// smallest positive level 2^-kg.
    pub qlo: f32,
}

impl KernelQAdam {
    pub fn load(rt: &Arc<Runtime>, artifacts: &Path, manifest: &Manifest) -> Result<Self> {
        let graph = rt.load(&artifacts.join(&manifest.optimizer.qadam_artifact))?;
        Ok(Self { graph, chunk: manifest.optimizer.chunk })
    }

    /// One fused step over the full flat vectors. `m`, `v`, `e` are
    /// updated in place; the quantized delta is written to `qdelta`.
    pub fn step(
        &self,
        m: &mut [f32],
        v: &mut [f32],
        g: &[f32],
        e: &mut [f32],
        s: StepScalars,
        qdelta: &mut [f32],
    ) -> Result<()> {
        let n = m.len();
        assert!(v.len() == n && g.len() == n && e.len() == n && qdelta.len() == n);
        let c = self.chunk;
        let mut pad = vec![0.0f32; c]; // scratch for the ragged tail
        let mut off = 0;
        while off < n {
            let len = (n - off).min(c);
            let run_chunk = |mc: &[f32], vc: &[f32], gc: &[f32], ec: &[f32]| -> Result<Vec<xla::Literal>> {
                let inputs = vec![
                    literal_f32(mc, &[c])?,
                    literal_f32(vc, &[c])?,
                    literal_f32(gc, &[c])?,
                    literal_f32(ec, &[c])?,
                    literal_scalar(s.alpha),
                    literal_scalar(s.beta),
                    literal_scalar(s.theta),
                    literal_scalar(s.eps),
                    literal_scalar(s.qlo),
                ];
                self.graph.run(&inputs)
            };
            let outs = if len == c {
                run_chunk(&m[off..off + c], &v[off..off + c], &g[off..off + c], &e[off..off + c])?
            } else {
                // pad the tail chunk with zeros per buffer
                let mut padded = |src: &[f32]| -> Vec<f32> {
                    pad[..len].copy_from_slice(src);
                    pad[len..].fill(0.0);
                    pad.clone()
                };
                let (pm, pv, pg, pe) = (
                    padded(&m[off..off + len]),
                    padded(&v[off..off + len]),
                    padded(&g[off..off + len]),
                    padded(&e[off..off + len]),
                );
                run_chunk(&pm, &pv, &pg, &pe)?
            };
            debug_assert_eq!(outs.len(), 4);
            let mut tmp = vec![0.0f32; c];
            outs[0].copy_raw_to(&mut tmp)?;
            m[off..off + len].copy_from_slice(&tmp[..len]);
            outs[1].copy_raw_to(&mut tmp)?;
            v[off..off + len].copy_from_slice(&tmp[..len]);
            outs[2].copy_raw_to(&mut tmp)?;
            qdelta[off..off + len].copy_from_slice(&tmp[..len]);
            outs[3].copy_raw_to(&mut tmp)?;
            e[off..off + len].copy_from_slice(&tmp[..len]);
            off += len;
        }
        Ok(())
    }
}

/// PJRT/Pallas-backed implementation of the paper's worker optimizer —
/// the flagship hot path. Numerically mirrors
/// [`crate::optim::QAdamEf`] (asserted by the integration tests) but the
/// moment/quantization math runs inside the AOT-compiled Pallas kernel.
pub struct PjrtQAdam {
    kernel: Arc<KernelQAdam>,
    m: Vec<f32>,
    v: Vec<f32>,
    e: Vec<f32>,
    qdelta: Vec<f32>,
    lq: crate::quant::LogQuant,
    pub lr: crate::optim::LrSchedule,
    pub theta: crate::optim::ThetaSchedule,
    pub beta: f32,
    pub eps: f32,
}

impl PjrtQAdam {
    pub fn new(
        kernel: Arc<KernelQAdam>,
        dim: usize,
        kg: u32,
        lr: crate::optim::LrSchedule,
    ) -> Self {
        Self {
            kernel,
            m: vec![0.0; dim],
            v: vec![0.0; dim],
            e: vec![0.0; dim],
            qdelta: vec![0.0; dim],
            lq: crate::quant::LogQuant::new(kg),
            lr,
            theta: crate::optim::ThetaSchedule::Const { theta: crate::defaults::THETA },
            beta: crate::defaults::BETA,
            eps: crate::defaults::EPS,
        }
    }
}

impl crate::optim::WorkerOpt for PjrtQAdam {
    fn step(
        &mut self,
        grad: &[f32],
        t: u64,
        epoch: u64,
        _rng: &mut crate::util::DetRng,
    ) -> crate::quant::DeltaMsg {
        let s = StepScalars {
            alpha: self.lr.at(t, epoch),
            beta: self.beta,
            theta: self.theta.at(t),
            eps: self.eps,
            qlo: f32::exp2(-(self.lq.kg as f32)),
        };
        self.kernel
            .step(&mut self.m, &mut self.v, grad, &mut self.e, s, &mut self.qdelta)
            .expect("qadam kernel step");
        // The wire message is rebuilt per chunk (per-chunk scale).
        let chunk = self.kernel.chunk;
        let mut scales = Vec::with_capacity(self.qdelta.len().div_ceil(chunk));
        let mut codes: Vec<u32> = Vec::with_capacity(self.qdelta.len());
        for piece in self.qdelta.chunks(chunk) {
            let s = piece.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            scales.push(s);
            codes.extend(self.lq.encode_quantized(piece, s));
        }
        crate::quant::DeltaMsg::Single(crate::quant::WireMsg {
            codec: crate::quant::CodecId::LogQuant,
            param: if scales.len() > 1 { self.lq.pjrt_param(chunk) } else { self.lq.kg },
            n: self.qdelta.len(),
            scales,
            codes: Some(crate::quant::pack::pack(&codes, self.lq.code_bits())),
            raw: vec![],
        })
    }

    fn name(&self) -> String {
        format!("qadam-pjrt[kg={}]", self.lq.kg)
    }

    fn bits_per_element(&self) -> f64 {
        self.lq.code_bits() as f64
    }

    fn residual_norm(&self) -> f32 {
        self.e.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}
