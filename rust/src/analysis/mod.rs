//! Static analysis: the `qadam lint` invariant analyzer.
//!
//! The correctness claims this repo makes — fixed-seed bit-parity
//! across engines and shards, zero steady-state allocation in the codec
//! hot path, panic-free wire decoding — are invariants the compiler
//! cannot see. The runtime suites (`kernel_equiv`, `alloc_regression`,
//! `shard_parity`) catch violations after the fact on covered paths;
//! this module catches them at the source level, on every path, before
//! a test runs.
//!
//! Layout: [`scanner`] is the language layer (comment/literal
//! sanitization, function spans, annotations, waivers) and [`rules`]
//! holds the five invariant rules. [`run`] walks `rust/src/`, applies
//! every rule, and pins the crate-wide `unsafe` inventory to
//! [`UNSAFE_BUDGET`]. The registry itself is versioned
//! ([`REGISTRY_VERSION`]) and surfaced through `qadam info` so external
//! probes can assert which rule set a binary enforces.
//!
//! Annotations recognized in source:
//! - `// qadam: hotpath` — next `fn` is in INV-ALLOC scope
//! - `// qadam: decode` — next `fn` is in INV-PANIC scope (functions
//!   named `*from_bytes*` are in scope automatically)
//! - `// lint: allow(INV-XXX) <reason>` — waive one rule on the line
//!   below (or the same line); the reason is mandatory and every
//!   honored waiver is reported in the lint output

pub mod rules;
pub mod scanner;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

pub use rules::{check_file, check_wire, FileReport, Finding, Waiver};

/// Version of the rule registry below. Bump whenever a rule is added,
/// removed, or materially re-scoped; `qadam info` reports it so probes
/// (and `scripts/ci.sh`) can assert what a binary enforces.
pub const REGISTRY_VERSION: u32 = 1;

/// The committed crate-wide `unsafe` inventory: the four
/// `unsafe impl Send/Sync` for the PJRT `Runtime`/`Graph` wrappers in
/// `runtime/mod.rs` (audited there; see the SAFETY blocks). Any new
/// `unsafe` site fails INV-SAFETY until it is audited and this budget
/// is re-pinned in the same commit.
pub const UNSAFE_BUDGET: usize = 4;

/// One entry in the invariant registry.
pub struct Rule {
    pub id: &'static str,
    pub summary: &'static str,
}

/// The registry, in report order.
pub const RULES: &[Rule] = &[
    Rule {
        id: rules::INV_ALLOC,
        summary: "no allocating calls inside `// qadam: hotpath` functions",
    },
    Rule {
        id: rules::INV_DET,
        summary: "no wall-clock, OS-rng, or hash-order reads in ps/, quant/, elastic/",
    },
    Rule {
        id: rules::INV_PANIC,
        summary: "no unwrap/expect/panic/indexing in from_bytes and `// qadam: decode` functions",
    },
    Rule {
        id: rules::INV_SAFETY,
        summary: "every `unsafe` carries `// SAFETY:`; inventory pinned to the committed budget",
    },
    Rule {
        id: rules::INV_WIRE,
        summary: "every ps/protocol.rs frame tag is pinned in wire_golden.rs and `qadam info`",
    },
];

/// Outcome of a full-tree lint run.
pub struct Report {
    /// Number of `.rs` files scanned under `rust/src/`.
    pub files: usize,
    /// Violations, sorted by (path, line, rule). Empty ⇒ tree is clean.
    pub findings: Vec<Finding>,
    /// Honored `// lint: allow(...)` waivers, for visibility.
    pub waivers: Vec<Waiver>,
    /// Non-test `unsafe` sites found (compared against [`UNSAFE_BUDGET`]).
    pub unsafe_count: usize,
}

/// Walk upward from `start` to the repo root (the directory containing
/// `rust/src/lib.rs`).
pub fn repo_root_from(start: &Path) -> Option<PathBuf> {
    let mut cur = Some(start.to_path_buf());
    while let Some(d) = cur {
        if d.join("rust").join("src").join("lib.rs").is_file() {
            return Some(d);
        }
        cur = d.parent().map(Path::to_path_buf);
    }
    None
}

/// Lint the tree rooted at `root` (the repo root). Deterministic: files
/// are walked in sorted order and findings are fully ordered.
pub fn run(root: &Path) -> Result<Report> {
    let src = root.join("rust").join("src");
    let mut files = Vec::new();
    collect_rs(&src, &mut files)?;
    files.sort();

    let mut report =
        Report { files: 0, findings: Vec::new(), waivers: Vec::new(), unsafe_count: 0 };
    let mut sources: Vec<(String, String)> = Vec::new();
    for f in &files {
        let text =
            std::fs::read_to_string(f).map_err(|e| anyhow!("reading {}: {e}", f.display()))?;
        let rel = rel_path(root, f);
        let fr = rules::check_file(&rel, &text);
        report.files += 1;
        report.unsafe_count += fr.unsafe_count;
        report.findings.extend(fr.findings);
        report.waivers.extend(fr.waivers);
        sources.push((rel, text));
    }

    // INV-WIRE is cross-file: protocol tags vs golden fixtures vs the
    // `qadam info` emitter.
    let protocol = sources.iter().find(|(p, _)| p.ends_with("ps/protocol.rs"));
    let info = sources.iter().find(|(p, _)| p.ends_with("src/main.rs"));
    let golden = std::fs::read_to_string(root.join("rust").join("tests").join("wire_golden.rs"));
    match (protocol, info, golden) {
        (Some((_, proto)), Some((_, main_src)), Ok(golden_src)) => {
            report.findings.extend(rules::check_wire(proto, &golden_src, main_src));
        }
        _ => report.findings.push(Finding {
            rule: rules::INV_WIRE,
            path: "rust".to_string(),
            line: 0,
            msg: "cannot check the tag registry: ps/protocol.rs, src/main.rs, or \
                  tests/wire_golden.rs is missing"
                .to_string(),
        }),
    }

    // INV-SAFETY crate-wide pins: the inventory budget and the
    // unsafe-op-in-unsafe-fn backstop.
    if report.unsafe_count != UNSAFE_BUDGET {
        report.findings.push(Finding {
            rule: rules::INV_SAFETY,
            path: "rust/src".to_string(),
            line: 0,
            msg: format!(
                "unsafe inventory is {} sites but the committed budget is {} — audit the \
                 changed site(s) and re-pin analysis::UNSAFE_BUDGET in the same commit",
                report.unsafe_count, UNSAFE_BUDGET
            ),
        });
    }
    if let Some((_, lib)) = sources.iter().find(|(p, _)| p.ends_with("src/lib.rs")) {
        if !lib.contains("#![deny(unsafe_op_in_unsafe_fn)]") {
            report.findings.push(Finding {
                rule: rules::INV_SAFETY,
                path: "rust/src/lib.rs".to_string(),
                line: 1,
                msg: "`#![deny(unsafe_op_in_unsafe_fn)]` is missing from the crate root"
                    .to_string(),
            });
        }
    }

    report.findings.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    report.waivers.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    Ok(report)
}

/// Recursively collect `.rs` files, sorted at every level so the walk
/// order (and thus finding order) is stable across platforms.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let rd = std::fs::read_dir(dir).map_err(|e| anyhow!("reading {}: {e}", dir.display()))?;
    let mut entries = Vec::new();
    for e in rd {
        entries.push(e.map_err(|err| anyhow!("listing {}: {err}", dir.display()))?.path());
    }
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().and_then(|x| x.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Repo-relative, `/`-separated path for reports.
fn rel_path(root: &Path, f: &Path) -> String {
    let rel = f.strip_prefix(root).unwrap_or(f);
    rel.to_string_lossy().replace('\\', "/")
}
