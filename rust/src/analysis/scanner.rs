//! Text-level Rust source scanner behind `qadam lint`.
//!
//! Deliberately dependency-free (no `syn`, no proc-macro machinery —
//! this crate builds offline against only `xla` + `anyhow`): the
//! scanner strips string/char literals and comments with a small state
//! machine, then recognizes just enough structure — function spans by
//! brace matching, `#[cfg(test)] mod` spans, annotation and waiver
//! comments — for the rules in [`super::rules`] to match tokens without
//! false positives from literals or prose.
//!
//! Precision contract: token matching runs over [`Line::code`] (string
//! and comment contents blanked), so `"Instant::now"` inside a string
//! or a doc comment never fires; annotations and waivers are read from
//! [`Line::comment`], so code can never fake one.

/// One source line after sanitization.
#[derive(Debug, Default)]
pub struct Line {
    /// Code with comments removed and string/char-literal *contents*
    /// blanked (delimiters kept, so expression shape survives).
    pub code: String,
    /// Comment text on this line (line, block and doc comments alike).
    pub comment: String,
}

/// Split `text` into sanitized lines. Handles nested block comments,
/// string/raw-string/byte-string literals (including multi-line ones),
/// char literals and lifetimes.
pub fn sanitize(text: &str) -> Vec<Line> {
    #[derive(Clone, Copy, PartialEq)]
    enum Mode {
        Code,
        /// Inside `/* ... */`, with nesting depth.
        Block(u32),
        /// Inside a `"..."` (or `b"..."`) literal.
        Str,
        /// Inside a raw string, with the closing `#` count.
        RawStr(usize),
    }
    let mut mode = Mode::Code;
    let mut lines = Vec::new();
    for raw in text.split('\n') {
        let cs: Vec<char> = raw.chars().collect();
        let mut line = Line::default();
        let mut i = 0usize;
        while i < cs.len() {
            match mode {
                Mode::Block(depth) => {
                    if cs[i] == '*' && cs.get(i + 1) == Some(&'/') {
                        mode = if depth <= 1 { Mode::Code } else { Mode::Block(depth - 1) };
                        i += 2;
                    } else if cs[i] == '/' && cs.get(i + 1) == Some(&'*') {
                        mode = Mode::Block(depth + 1);
                        i += 2;
                    } else {
                        line.comment.push(cs[i]);
                        i += 1;
                    }
                }
                Mode::Str => {
                    if cs[i] == '\\' {
                        i += 2; // skip the escaped char (may end the line)
                    } else if cs[i] == '"' {
                        line.code.push('"');
                        mode = Mode::Code;
                        i += 1;
                    } else {
                        i += 1;
                    }
                }
                Mode::RawStr(hashes) => {
                    let closes = cs[i] == '"'
                        && (1..=hashes).all(|k| cs.get(i + k) == Some(&'#'));
                    if closes {
                        line.code.push('"');
                        mode = Mode::Code;
                        i += 1 + hashes;
                    } else {
                        i += 1;
                    }
                }
                Mode::Code => {
                    let c = cs[i];
                    let prev_ident = line
                        .code
                        .chars()
                        .next_back()
                        .is_some_and(|p| p.is_alphanumeric() || p == '_');
                    if c == '/' && cs.get(i + 1) == Some(&'/') {
                        // line comment: the rest of the line, sans the
                        // leading slashes / doc-comment markers
                        let rest: String = cs[i..].iter().collect();
                        line.comment.push_str(
                            rest.trim_start_matches('/').trim_start_matches('!'),
                        );
                        break;
                    } else if c == '/' && cs.get(i + 1) == Some(&'*') {
                        mode = Mode::Block(1);
                        i += 2;
                    } else if c == '"' {
                        line.code.push('"');
                        mode = Mode::Str;
                        i += 1;
                    } else if c == 'r' && !prev_ident && is_raw_str_start(&cs, i + 1) {
                        let hashes = count_hashes(&cs, i + 1);
                        line.code.push('"');
                        mode = Mode::RawStr(hashes);
                        i += 2 + hashes; // r, #*, "
                    } else if c == 'b' && !prev_ident && cs.get(i + 1) == Some(&'"') {
                        line.code.push('"');
                        mode = Mode::Str;
                        i += 2;
                    } else if c == 'b' && !prev_ident && cs.get(i + 1) == Some(&'r')
                        && is_raw_str_start(&cs, i + 2)
                    {
                        let hashes = count_hashes(&cs, i + 2);
                        line.code.push('"');
                        mode = Mode::RawStr(hashes);
                        i += 3 + hashes;
                    } else if c == 'b' && !prev_ident && cs.get(i + 1) == Some(&'\'') {
                        i += 1; // byte-char literal: fall through to '\''
                    } else if c == '\'' {
                        if cs.get(i + 1) == Some(&'\\') {
                            // escaped char literal: skip to the closing quote
                            let mut j = i + 3;
                            while j < cs.len() && cs[j] != '\'' {
                                j += 1;
                            }
                            i = j + 1;
                        } else if cs.get(i + 2) == Some(&'\'') {
                            i += 3; // plain char literal 'x'
                        } else {
                            line.code.push('\''); // a lifetime
                            i += 1;
                        }
                    } else {
                        line.code.push(c);
                        i += 1;
                    }
                }
            }
        }
        lines.push(line);
    }
    lines
}

/// Is `cs[at..]` the `#*"` tail of a raw-string opener?
fn is_raw_str_start(cs: &[char], at: usize) -> bool {
    let hashes = count_hashes(cs, at);
    cs.get(at + hashes) == Some(&'"')
}

fn count_hashes(cs: &[char], at: usize) -> usize {
    cs[at.min(cs.len())..].iter().take_while(|&&c| c == '#').count()
}

/// Does `s` contain `word` with non-identifier characters (or edges) on
/// both sides?
pub fn has_word(s: &str, word: &str) -> bool {
    let bytes = s.as_bytes();
    let is_ident = |b: u8| b.is_ascii_alphanumeric() || b == b'_';
    let mut from = 0usize;
    while let Some(pos) = s.get(from..).and_then(|t| t.find(word)) {
        let at = from + pos;
        let end = at + word.len();
        let left_ok = at == 0 || !bytes.get(at - 1).copied().is_some_and(is_ident);
        let right_ok = !bytes.get(end).copied().is_some_and(is_ident);
        if left_ok && right_ok {
            return true;
        }
        from = at + 1;
    }
    false
}

/// Does the sanitized code contain an *index expression* (`expr[...]`)?
/// A `[` counts when the previous non-space character ends an
/// expression — an identifier (that is not a keyword), `)` or `]`.
/// Attributes (`#[...]`), array/slice types (`[u8; 4]`, `&[f32]`),
/// array literals and slice patterns all miss that test.
pub fn has_index_expr(code: &str) -> bool {
    const KEYWORDS: &[&str] = &[
        "mut", "in", "return", "if", "else", "match", "ref", "move", "as", "dyn", "impl",
        "where", "for", "while", "let", "const", "static", "box", "break", "loop",
    ];
    let cs: Vec<char> = code.chars().collect();
    for (i, &c) in cs.iter().enumerate() {
        if c != '[' {
            continue;
        }
        let mut j = i;
        let mut prev = None;
        while j > 0 {
            j -= 1;
            if cs[j] != ' ' {
                prev = Some((j, cs[j]));
                break;
            }
        }
        let (at, p) = match prev {
            Some(v) => v,
            None => continue,
        };
        if p == ')' || p == ']' {
            return true;
        }
        if p.is_alphanumeric() || p == '_' {
            // walk the identifier back; keywords are not expressions
            let mut s = at;
            while s > 0 && (cs[s - 1].is_alphanumeric() || cs[s - 1] == '_') {
                s -= 1;
            }
            let ident: String = cs[s..=at].iter().collect();
            if !KEYWORDS.contains(&ident.as_str()) {
                return true;
            }
        }
    }
    false
}

/// One function's span in a sanitized file.
#[derive(Debug)]
pub struct FnSpan {
    pub name: String,
    /// Line of the `fn` keyword (0-based).
    pub start: usize,
    /// Last body line, inclusive (== `start` for bodyless trait decls).
    pub end: usize,
    /// Preceded by a `// qadam: hotpath` annotation.
    pub hotpath: bool,
    /// Preceded by a `// qadam: decode` annotation.
    pub decode: bool,
}

/// Find every `fn` item and its body span. Annotation comments
/// (`// qadam: hotpath`, `// qadam: decode`) bind to the next `fn`,
/// surviving only blank, comment-only and attribute lines in between.
pub fn fn_spans(lines: &[Line]) -> Vec<FnSpan> {
    let mut spans = Vec::new();
    let mut pending_hot = false;
    let mut pending_decode = false;
    for (idx, line) in lines.iter().enumerate() {
        if line.comment.contains("qadam: hotpath") {
            pending_hot = true;
        }
        if line.comment.contains("qadam: decode") {
            pending_decode = true;
        }
        let trimmed = line.code.trim();
        let decl = has_word(&line.code, "fn").then(|| fn_name(&line.code));
        match decl {
            Some(Some((name, after))) => {
                let end = item_end(lines, idx, after);
                spans.push(FnSpan {
                    name,
                    start: idx,
                    end,
                    hotpath: pending_hot,
                    decode: pending_decode,
                });
                pending_hot = false;
                pending_decode = false;
            }
            _ => {
                // any other real code line breaks the annotation chain
                if !trimmed.is_empty() && !trimmed.starts_with("#[") && !trimmed.starts_with("#!") {
                    pending_hot = false;
                    pending_decode = false;
                }
            }
        }
    }
    spans
}

/// Parse `fn <name>` out of a sanitized code line; returns the name and
/// the char offset just past it. `None` for `fn` pointer types and the
/// like (no identifier follows).
fn fn_name(code: &str) -> Option<(String, usize)> {
    let cs: Vec<char> = code.chars().collect();
    let is_ident = |c: char| c.is_alphanumeric() || c == '_';
    let mut i = 0usize;
    while i + 2 <= cs.len() {
        let word_here = cs[i] == 'f'
            && cs.get(i + 1) == Some(&'n')
            && (i == 0 || !is_ident(cs[i - 1]))
            && !cs.get(i + 2).copied().is_some_and(is_ident);
        if !word_here {
            i += 1;
            continue;
        }
        let mut j = i + 2;
        while cs.get(j) == Some(&' ') {
            j += 1;
        }
        let start = j;
        while cs.get(j).copied().is_some_and(is_ident) {
            j += 1;
        }
        if j > start {
            return Some((cs[start..j].iter().collect(), j));
        }
        i += 2;
    }
    None
}

/// Walk from `(start_line, start_char)` to the end of the item: the
/// first top-level `;` (bodyless declaration) ends it on that line; a
/// `{` opens the body, which ends where braces balance. `;` inside
/// `()`/`[]`/`<>`-free bracket nesting (e.g. `-> [u8; 4]`) is not a
/// terminator.
fn item_end(lines: &[Line], start_line: usize, start_char: usize) -> usize {
    let mut depth = 0i32; // ( and [
    let mut braces = 0i32;
    let mut in_body = false;
    let mut first = start_char;
    for (li, line) in lines.iter().enumerate().skip(start_line) {
        for c in line.code.chars().skip(if li == start_line { first } else { 0 }) {
            match c {
                '(' | '[' => depth += 1,
                ')' | ']' => depth -= 1,
                ';' if !in_body && depth <= 0 => return li,
                '{' => {
                    in_body = true;
                    braces += 1;
                }
                '}' if in_body => {
                    braces -= 1;
                    if braces == 0 {
                        return li;
                    }
                }
                _ => {}
            }
        }
        first = 0;
    }
    lines.len().saturating_sub(1)
}

/// Mark every line inside a `#[cfg(test)] mod … { … }` span. Rules skip
/// these: tests legitimately `unwrap()`, allocate and index.
pub fn test_lines(lines: &[Line]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut i = 0usize;
    while i < lines.len() {
        if lines[i].code.contains("#[cfg(test)]") {
            // find the gated item (skip further attributes/blank lines)
            let mut j = i + 1;
            while j < lines.len() {
                let t = lines[j].code.trim();
                if t.is_empty() || t.starts_with("#[") {
                    j += 1;
                } else {
                    break;
                }
            }
            if j < lines.len() && has_word(&lines[j].code, "mod") {
                let end = item_end(lines, j, 0);
                for m in mask.iter_mut().take(end + 1).skip(i) {
                    *m = true;
                }
                i = end + 1;
                continue;
            }
        }
        i += 1;
    }
    mask
}

/// The outcome of looking for a `// lint: allow(RULE) reason` waiver
/// covering a finding.
#[derive(Debug, PartialEq)]
pub enum Allowance {
    /// No waiver — report the finding.
    None,
    /// Waived, with a non-empty justification.
    Justified(String),
    /// A waiver comment with no justification — itself a violation.
    Unjustified,
}

/// Look for a waiver of `rule` at `line`: its own comment, or the
/// contiguous run of comment-only lines directly above it.
pub fn allowance(lines: &[Line], line: usize, rule: &str) -> Allowance {
    let needle = format!("lint: allow({rule})");
    let mut best = Allowance::None;
    let mut check = |l: &Line| {
        if let Some(pos) = l.comment.find(&needle) {
            let reason = l.comment[pos + needle.len()..].trim();
            if reason.is_empty() {
                if best == Allowance::None {
                    best = Allowance::Unjustified;
                }
            } else {
                best = Allowance::Justified(reason.to_string());
            }
        }
    };
    if let Some(l) = lines.get(line) {
        check(l);
    }
    let mut j = line;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        if !l.code.trim().is_empty() {
            break; // a code line ends the comment run
        }
        if l.comment.trim().is_empty() {
            break; // so does a fully blank line
        }
        check(l);
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_blanked() {
        let src = "let x = \"Instant::now()\"; // Instant::now in prose\nlet y = 1;";
        let lines = sanitize(src);
        assert!(!lines[0].code.contains("Instant::now"));
        assert!(lines[0].comment.contains("Instant::now in prose"));
        assert_eq!(lines[1].code, "let y = 1;");
    }

    #[test]
    fn raw_and_byte_strings_are_blanked() {
        let src = "let a = r#\"unwrap() . b[0]\"#; let b = b\"x[1]\"; let c = 'x';";
        let lines = sanitize(src);
        assert!(!lines[0].code.contains("unwrap"));
        assert!(!has_index_expr(&lines[0].code), "{}", lines[0].code);
    }

    #[test]
    fn multiline_strings_and_block_comments() {
        let src = "let s = \"line one\n .unwrap() two\";\n/* block\n.unwrap()\n*/ let t = 3;";
        let lines = sanitize(src);
        assert!(lines.iter().all(|l| !l.code.contains(".unwrap()")));
        assert_eq!(lines[4].code.trim(), "let t = 3;");
    }

    #[test]
    fn lifetimes_survive_char_literal_handling() {
        let lines = sanitize("fn f<'a>(x: &'a [u8]) -> char { '\\'' }");
        assert!(lines[0].code.contains("<'a>"));
        assert!(!lines[0].code.contains("\\'"));
    }

    #[test]
    fn word_matching_respects_boundaries() {
        assert!(has_word("use std::collections::HashMap;", "HashMap"));
        assert!(!has_word("deny(unsafe_op_in_unsafe_fn)", "unsafe"));
        assert!(has_word("unsafe impl Send for X {}", "unsafe"));
    }

    #[test]
    fn index_detection() {
        assert!(has_index_expr("let x = b[0];"));
        assert!(has_index_expr("let y = words[i + 1];"));
        assert!(has_index_expr("f(a)[2]"));
        assert!(!has_index_expr("#[cfg(test)]"));
        assert!(!has_index_expr("let a: [u8; 4] = [0u8; 4];"));
        assert!(!has_index_expr("fn f(x: &mut [f32]) -> [u8; 4] {"));
        assert!(!has_index_expr("for v in [1, 2, 3] {"));
        assert!(!has_index_expr("let [a, b] = pair;"));
    }

    #[test]
    fn fn_spans_with_annotations() {
        let src = "\
// qadam: hotpath
fn hot(x: &mut [f32]) {
    x.fill(0.0);
}

fn cold() -> Vec<u8> {
    Vec::new()
}

// qadam: decode
#[inline]
fn parse_from_bytes(b: &[u8]) -> Option<u8> {
    b.first().copied()
}
";
        let spans = fn_spans(&sanitize(src));
        assert_eq!(spans.len(), 3);
        assert!(spans[0].hotpath && !spans[0].decode);
        assert_eq!((spans[0].name.as_str(), spans[0].start, spans[0].end), ("hot", 1, 3));
        assert!(!spans[1].hotpath);
        assert_eq!(spans[1].name, "cold");
        assert!(spans[2].decode, "annotation must survive an attribute line");
        assert_eq!(spans[2].name, "parse_from_bytes");
    }

    #[test]
    fn bodyless_and_array_return_spans() {
        let src = "trait T {\n    fn decl(&self) -> usize;\n    fn arr(&self) -> [u8; 4] {\n        [0; 4]\n    }\n}";
        let spans = fn_spans(&sanitize(src));
        assert_eq!(spans.len(), 2);
        assert_eq!((spans[0].start, spans[0].end), (1, 1), "decl ends at its `;`");
        assert_eq!((spans[1].start, spans[1].end), (2, 4), "`;` inside [u8; 4] is not an end");
    }

    #[test]
    fn cfg_test_mod_is_masked() {
        let src = "fn real() {}\n\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { x[0]; }\n}";
        let lines = sanitize(src);
        let mask = test_lines(&lines);
        assert_eq!(mask, vec![false, false, true, true, true, true, true]);
    }

    #[test]
    fn allowance_forms() {
        let lines = sanitize(
            "// lint: allow(INV-DET) deadline is wall-clock by design\nlet t = Instant::now();\n\n// lint: allow(INV-DET)\nlet u = Instant::now();\nlet v = Instant::now();\n",
        );
        assert!(matches!(allowance(&lines, 1, "INV-DET"), Allowance::Justified(_)));
        assert_eq!(allowance(&lines, 4, "INV-DET"), Allowance::Unjustified);
        assert_eq!(allowance(&lines, 5, "INV-DET"), Allowance::None);
    }
}
