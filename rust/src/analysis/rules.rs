//! The invariant rules behind `qadam lint` (see `DESIGN.md` §Static
//! analysis & invariants for the registry rationale).
//!
//! Per-file rules run over one sanitized source ([`check_file`]);
//! INV-WIRE is cross-file ([`check_wire`]). Every rule honors
//! `// lint: allow(RULE) reason` waivers — a waiver without a reason is
//! itself a finding, and honored waivers are reported so `qadam lint`
//! output always shows what was excused and why.

use super::scanner::{self, Allowance, Line};

pub const INV_ALLOC: &str = "INV-ALLOC";
pub const INV_DET: &str = "INV-DET";
pub const INV_PANIC: &str = "INV-PANIC";
pub const INV_SAFETY: &str = "INV-SAFETY";
pub const INV_WIRE: &str = "INV-WIRE";

/// Calls that allocate — banned inside `// qadam: hotpath` functions.
/// The zero-steady-state-allocation contract these protect is asserted
/// dynamically by `rust/tests/alloc_regression.rs`; the lint catches it
/// at the source level, on every path.
const ALLOC_TOKENS: &[&str] = &[
    "Vec::new",
    "vec!",
    ".to_vec()",
    ".clone()",
    "format!",
    "Box::new",
    "String::new",
    "String::from",
    ".to_string()",
    ".to_owned()",
    "with_capacity",
    ".collect()",
];

/// Panicking calls — banned in wire/checkpoint decode functions (any
/// `fn` whose name contains `from_bytes`, plus `// qadam: decode`
/// annotations). Direct indexing is detected structurally on top.
const PANIC_TOKENS: &[&str] =
    &[".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!(", "unimplemented!("];

/// Nondeterminism sources — banned in the decision paths of `ps/`,
/// `quant/` and `elastic/`, where order- or time-dependence silently
/// breaks the fixed-seed bit-parity suites (`shard_parity`,
/// `policy_parity`). Substring tokens.
const DET_CALL_TOKENS: &[&str] = &["Instant::now", "SystemTime::now", "thread_rng", "rand::"];

/// Hash-order containers (whole-word): iteration order varies run to
/// run, so any traversal that reaches output or wire bytes breaks
/// reproducibility. Use `BTreeMap`/`BTreeSet` instead.
const DET_TYPE_TOKENS: &[&str] = &["HashMap", "HashSet"];

/// Directories whose sources are in INV-DET scope.
fn det_scope(path: &str) -> bool {
    path.contains("src/ps/") || path.contains("src/quant/") || path.contains("src/elastic/")
}

/// One rule violation.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    /// Repo-relative path, `/`-separated.
    pub path: String,
    /// 1-based line (0 = file/crate-level finding).
    pub line: usize,
    pub msg: String,
}

/// One honored `// lint: allow(...)` waiver.
#[derive(Debug, Clone)]
pub struct Waiver {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    pub reason: String,
}

/// Everything one file contributes to a lint run.
#[derive(Debug, Default)]
pub struct FileReport {
    pub findings: Vec<Finding>,
    pub waivers: Vec<Waiver>,
    /// Non-test `unsafe` sites (counted against the crate budget).
    pub unsafe_count: usize,
}

impl FileReport {
    fn emit(&mut self, lines: &[Line], rule: &'static str, path: &str, li: usize, msg: String) {
        match scanner::allowance(lines, li, rule) {
            Allowance::Justified(reason) => {
                self.waivers.push(Waiver { rule, path: path.to_string(), line: li + 1, reason });
            }
            Allowance::Unjustified => self.findings.push(Finding {
                rule,
                path: path.to_string(),
                line: li + 1,
                msg: format!(
                    "{msg} — and the waiver comment has no justification \
                     (add a reason after `lint: allow({rule})`)"
                ),
            }),
            Allowance::None => {
                self.findings.push(Finding { rule, path: path.to_string(), line: li + 1, msg });
            }
        }
    }
}

/// Run every per-file rule over one source. `path` is the repo-relative
/// path (it selects INV-DET scope); `text` is the raw source.
pub fn check_file(path: &str, text: &str) -> FileReport {
    let lines = scanner::sanitize(text);
    let tests = scanner::test_lines(&lines);
    let spans = scanner::fn_spans(&lines);
    let mut rep = FileReport::default();

    // INV-ALLOC: hotpath functions must not allocate.
    for sp in spans.iter().filter(|s| s.hotpath) {
        for li in sp.start..=sp.end {
            if tests[li] {
                continue;
            }
            for tok in ALLOC_TOKENS {
                if lines[li].code.contains(tok) {
                    rep.emit(
                        &lines,
                        INV_ALLOC,
                        path,
                        li,
                        format!("`{tok}` allocates inside hot function `{}`", sp.name),
                    );
                }
            }
        }
    }

    // INV-PANIC: decode functions must be total.
    for sp in spans.iter().filter(|s| s.decode || s.name.contains("from_bytes")) {
        for li in sp.start..=sp.end {
            if tests[li] {
                continue;
            }
            for tok in PANIC_TOKENS {
                if lines[li].code.contains(tok) {
                    rep.emit(
                        &lines,
                        INV_PANIC,
                        path,
                        li,
                        format!("`{tok}` can panic inside decode function `{}`", sp.name),
                    );
                }
            }
            if scanner::has_index_expr(&lines[li].code) {
                rep.emit(
                    &lines,
                    INV_PANIC,
                    path,
                    li,
                    format!(
                        "direct indexing inside decode function `{}` (use util::bytes / `.get()`)",
                        sp.name
                    ),
                );
            }
        }
    }

    // INV-DET: no nondeterminism sources in decision-path modules.
    if det_scope(path) {
        for (li, line) in lines.iter().enumerate() {
            if tests[li] {
                continue;
            }
            for tok in DET_CALL_TOKENS {
                if line.code.contains(tok) {
                    rep.emit(
                        &lines,
                        INV_DET,
                        path,
                        li,
                        format!("`{tok}` is nondeterministic in a bit-parity decision path"),
                    );
                }
            }
            for tok in DET_TYPE_TOKENS {
                if scanner::has_word(&line.code, tok) {
                    rep.emit(
                        &lines,
                        INV_DET,
                        path,
                        li,
                        format!("`{tok}` iteration order is nondeterministic (use BTree{})",
                            tok.trim_start_matches("Hash")),
                    );
                }
            }
        }
    }

    // INV-SAFETY: every unsafe site carries a SAFETY justification.
    for (li, line) in lines.iter().enumerate() {
        if tests[li] || !scanner::has_word(&line.code, "unsafe") {
            continue;
        }
        rep.unsafe_count += 1;
        if !safety_documented(&lines, li) {
            rep.emit(
                &lines,
                INV_SAFETY,
                path,
                li,
                "`unsafe` without a `// SAFETY:` justification".to_string(),
            );
        }
    }

    rep.findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    rep
}

/// Is there a `SAFETY:` comment on this line or the contiguous run of
/// comment / attribute / further-`unsafe` lines directly above it?
/// (Stacked `unsafe impl Send`/`Sync` pairs share one block.)
fn safety_documented(lines: &[Line], li: usize) -> bool {
    if lines[li].comment.contains("SAFETY:") {
        return true;
    }
    let mut j = li;
    let mut budget = 40usize;
    while j > 0 && budget > 0 {
        j -= 1;
        budget -= 1;
        let l = &lines[j];
        let code = l.code.trim();
        let comment_only = code.is_empty() && !l.comment.trim().is_empty();
        let carries = comment_only || code.starts_with("#[") || scanner::has_word(code, "unsafe");
        if !carries {
            return false;
        }
        if l.comment.contains("SAFETY:") {
            return true;
        }
    }
    false
}

/// INV-WIRE, the cross-file rule: every `pub const NAME: u8` in
/// `ps/protocol.rs`'s `tag` module must appear (as code, not prose) in
/// both the golden-fixture suite and the `qadam info` capability JSON
/// emitter. A new frame kind therefore cannot ship without a
/// byte-pinned fixture and operator visibility.
pub fn check_wire(protocol: &str, golden: &str, info: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    let tags = tag_constants(protocol);
    if tags.is_empty() {
        out.push(Finding {
            rule: INV_WIRE,
            path: "rust/src/ps/protocol.rs".to_string(),
            line: 0,
            msg: "no `pub const NAME: u8` frame tags found in the `tag` module".to_string(),
        });
        return out;
    }
    let golden_code = code_of(golden);
    let info_code = code_of(info);
    for (name, line) in tags {
        if !scanner::has_word(&golden_code, &name) {
            out.push(Finding {
                rule: INV_WIRE,
                path: "rust/src/ps/protocol.rs".to_string(),
                line,
                msg: format!("frame tag `{name}` is not pinned in rust/tests/wire_golden.rs"),
            });
        }
        if !scanner::has_word(&info_code, &name) {
            out.push(Finding {
                rule: INV_WIRE,
                path: "rust/src/ps/protocol.rs".to_string(),
                line,
                msg: format!(
                    "frame tag `{name}` is not surfaced by the `qadam info` capability JSON"
                ),
            });
        }
    }
    out
}

/// The sanitized code of a whole source (comments/literals blanked).
fn code_of(text: &str) -> String {
    let lines = scanner::sanitize(text);
    let mut out = String::new();
    for l in &lines {
        out.push_str(&l.code);
        out.push('\n');
    }
    out
}

/// `(name, 1-based line)` of every `pub const NAME: u8` inside the
/// `tag` module of the protocol source.
fn tag_constants(protocol: &str) -> Vec<(String, usize)> {
    let lines = scanner::sanitize(protocol);
    let mut out = Vec::new();
    let mut inside = false;
    let mut depth = 0i32;
    for (i, l) in lines.iter().enumerate() {
        if !inside {
            if scanner::has_word(&l.code, "mod") && scanner::has_word(&l.code, "tag") {
                inside = true;
            } else {
                continue;
            }
        }
        for c in l.code.chars() {
            match c {
                '{' => depth += 1,
                '}' => depth -= 1,
                _ => {}
            }
        }
        let t = l.code.trim();
        if let Some(rest) = t.strip_prefix("pub const ") {
            if let Some((name, tail)) = rest.split_once(':') {
                if tail.trim_start().starts_with("u8") {
                    out.push((name.trim().to_string(), i + 1));
                }
            }
        }
        if depth <= 0 && l.code.contains('}') {
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_rule_fires_only_in_hot_spans() {
        let src = "\
// qadam: hotpath
fn hot(out: &mut [f32]) {
    let v = out.to_vec();
    out.copy_from_slice(&v);
}

fn cold() -> Vec<f32> {
    Vec::new()
}
";
        let rep = check_file("rust/src/quant/x.rs", src);
        assert_eq!(rep.findings.len(), 1, "{:?}", rep.findings);
        assert_eq!(rep.findings[0].rule, INV_ALLOC);
        assert_eq!(rep.findings[0].line, 3);
    }

    #[test]
    fn panic_rule_catches_named_and_annotated_decoders() {
        let src = "\
pub fn thing_from_bytes(b: &[u8]) -> u8 {
    b[0]
}

// qadam: decode
pub fn load(b: &[u8]) -> u32 {
    u32::from_le_bytes(b.get(0..4).map(|s| s.try_into().unwrap()).unwrap_or([0; 4]))
}
";
        let rep = check_file("rust/src/ps/x.rs", src);
        let rules: Vec<_> = rep.findings.iter().map(|f| (f.rule, f.line)).collect();
        assert!(rules.contains(&(INV_PANIC, 2)), "{rules:?}");
        assert!(rules.contains(&(INV_PANIC, 7)), "{rules:?}");
    }

    #[test]
    fn det_rule_is_scoped_and_waivable() {
        let src = "\
use std::time::Instant;
pub fn f() -> std::time::Instant {
    // lint: allow(INV-DET) deadline is wall-clock by design
    Instant::now()
}
";
        let in_scope = check_file("rust/src/ps/x.rs", src);
        assert!(in_scope.findings.is_empty(), "{:?}", in_scope.findings);
        assert_eq!(in_scope.waivers.len(), 1);
        let out_of_scope = check_file("rust/src/util/x.rs", src);
        assert!(out_of_scope.findings.is_empty() && out_of_scope.waivers.is_empty());
    }

    #[test]
    fn safety_rule_counts_and_requires_justification() {
        let documented = "\
// SAFETY: all access serializes on LOCK.
unsafe impl Send for X {}
unsafe impl Sync for X {}
";
        let rep = check_file("rust/src/runtime/x.rs", documented);
        assert_eq!(rep.unsafe_count, 2);
        assert!(rep.findings.is_empty(), "{:?}", rep.findings);
        let bare = "unsafe impl Send for X {}\n";
        let rep = check_file("rust/src/runtime/x.rs", bare);
        assert_eq!(rep.unsafe_count, 1);
        assert_eq!(rep.findings.len(), 1);
        assert_eq!(rep.findings[0].rule, INV_SAFETY);
    }

    #[test]
    fn wire_rule_checks_both_sides() {
        let protocol = "\
pub mod tag {
    pub const TO_WORKER_SHUTDOWN: u8 = 0;
    pub const TO_WORKER_WEIGHTS: u8 = 1;
}
pub const WIRE_VERSION: u32 = 2;
";
        let both = "TO_WORKER_SHUTDOWN TO_WORKER_WEIGHTS";
        assert!(check_wire(protocol, both, both).is_empty());
        let missing = check_wire(protocol, "TO_WORKER_SHUTDOWN", both);
        assert_eq!(missing.len(), 1);
        assert!(missing[0].msg.contains("TO_WORKER_WEIGHTS"));
        assert!(missing[0].msg.contains("wire_golden"));
        // prose/comment mentions do not count
        let prose = "// TO_WORKER_SHUTDOWN TO_WORKER_WEIGHTS";
        assert_eq!(check_wire(protocol, prose, both).len(), 2);
        // an empty tag module is itself a finding
        assert_eq!(check_wire("fn nothing() {}", both, both).len(), 1);
    }
}
