//! # QAdam-EF — Quantized Adam with Error Feedback
//!
//! Reproduction of *"Quantized Adam with Error Feedback"* (Chen, Shen,
//! Huang, Liu; 2020): a parameter-server distributed Adam with
//! gradient quantization (log levels, ∞-norm scaled), weight
//! quantization (uniform grid), and worker-side error feedback.
//!
//! Layering (see DESIGN.md):
//!
//! * [`quant`] — compressors (`Q_g`, `Q_x`, TernGrad, blockwise-EF),
//!   bit-packing wire codecs, error-feedback state.
//! * [`optim`] — worker-side optimizers: QAdam-EF (Alg. 1/3), plain
//!   Adam, TernGrad-SGD and blockwise-momentum-SGD baselines.
//! * [`models`] — the `artifacts/manifest.json` contract with the JAX
//!   layer: parameter layouts, flatten/unflatten.
//! * [`data`] — synthetic vision / text datasets (CIFAR stand-ins).
//! * [`runtime`] — PJRT CPU runtime: loads `artifacts/*.hlo.txt`
//!   (model fwd/bwd graphs and the fused Pallas QAdam step kernel)
//!   and executes them from the request path. Python is never needed
//!   at run time.
//! * [`ps`] — the parameter-server system: block-parallel server
//!   (Alg. 2), the scale-out shard layer ([`ps::ShardedServer`]: N
//!   independent servers over contiguous ranges, one process/host
//!   each), worker (Alg. 3), transports behind one [`ps::Transport`]
//!   round contract (sequential / threaded in-proc, TCP — sharded
//!   rounds run as independent lanes), protocol + byte accounting.
//! * [`elastic`] — fault tolerance for the round protocol: membership
//!   and participation semantics, straggler policies with quorum, and
//!   the deterministic `ChaosPlan`/`ChaosTransport` fault injector.
//! * [`coordinator`] — experiment configs, the synchronous training
//!   driver, metrics/CSV logging.
//! * [`obs`] — observability: round-lifecycle span tracing behind an
//!   injected clock, the atomic metrics registry, and the exporters
//!   (`/metrics` Prometheus text, JSONL traces, `qadam top`). Timing
//!   happens only at the coordinator seam — never inside [`ps`] /
//!   [`quant`] — and the disabled path is a branch on a `None`.
//! * [`sim`] — synthetic stochastic nonconvex problems for the
//!   convergence-theory checks (Theorems 3.1–3.3).
//! * [`analysis`] — the `qadam lint` static analyzer: a dependency-free
//!   source scanner enforcing the repo's invariant registry (INV-ALLOC,
//!   INV-DET, INV-PANIC, INV-SAFETY, INV-WIRE) over `rust/src/`.

// Unsafe code is budgeted (see `analysis::UNSAFE_BUDGET`): every site
// carries a `// SAFETY:` comment and implicit unsafety inside `unsafe
// fn` bodies is rejected, so each operation is individually justified.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod analysis;
pub mod coordinator;
pub mod data;
pub mod elastic;
pub mod models;
pub mod obs;
pub mod optim;
pub mod ps;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod util;

/// Paper-default hyperparameters (§5.1).
pub mod defaults {
    /// Momentum parameter β (paper: 0.99).
    pub const BETA: f32 = 0.99;
    /// EMA parameter θ for the second moment (paper: 0.999).
    pub const THETA: f32 = 0.999;
    /// Adaptivity floor ε (paper: 1e-5).
    pub const EPS: f32 = 1e-5;
    /// Starting base learning rate (paper: 1e-3 by grid search).
    pub const ALPHA: f32 = 1e-3;
    /// Number of workers (paper: 8).
    pub const WORKERS: usize = 8;
    /// Per-worker batch size (paper: 16).
    pub const BATCH: usize = 16;
}
