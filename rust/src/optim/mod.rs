//! Worker-side optimizers.
//!
//! The unifying contract ([`WorkerOpt`]) is the paper's Alg. 3: given
//! the local stochastic gradient at the broadcast weights, produce the
//! compressed update message `delta_t^(i)`; the server applies
//! `x_{t+1} = x_t - mean_i decode(delta_t^(i))`
//! (the paper's Alg. 2 line 4 with the descent sign made explicit).
//!
//! * [`QAdamEf`] — the paper's method (Alg. 1 / Alg. 3): generic Adam
//!   moments + error feedback + any compressor (LogQuant by default).
//!   Has both a pure-Rust fused hot loop and a PJRT/Pallas-backed
//!   variant (see [`crate::runtime::KernelQAdam`]).
//! * [`TernGradSgd`] — TernGrad baseline: quantize `lr * g` stochastically
//!   (unbiased), no EF, no momentum (Wen et al. [39] base form).
//! * [`BlockwiseSgdEf`] — Zheng et al. [44]: momentum SGD update,
//!   blockwise sign compression, error feedback.

pub mod adam;
pub mod schedule;
pub mod worker_opt;

pub use adam::AdamState;
pub use schedule::{LrSchedule, ThetaSchedule};
pub use worker_opt::{BlockwiseSgdEf, QAdamEf, TernGradSgd, WorkerOpt};
