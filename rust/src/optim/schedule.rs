//! Hyperparameter schedules (Assumption 4 and the paper's §5.1 choices).

/// Base learning rate α_t.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LrSchedule {
    /// α_t = α (constant).
    Const { alpha: f32 },
    /// α_t = α / sqrt(t) — Assumption 4 / Theorems 3.1–3.3.
    InvSqrt { alpha: f32 },
    /// α_t = α / sqrt(T) for a fixed horizon — Corollaries 3.1.1/3.2.1/3.3.1.
    FixedHorizon { alpha: f32, horizon: u64 },
    /// Halve every `half_every` epochs starting from α — the paper's
    /// experimental choice (§5.1: halve every 50 epochs from 1e-3).
    ExpDecay { alpha: f32, half_every: u64 },
}

impl LrSchedule {
    /// `t` is the 1-based iteration, `epoch` the 0-based epoch.
    pub fn at(&self, t: u64, epoch: u64) -> f32 {
        match *self {
            LrSchedule::Const { alpha } => alpha,
            LrSchedule::InvSqrt { alpha } => alpha / (t.max(1) as f32).sqrt(),
            LrSchedule::FixedHorizon { alpha, horizon } => alpha / (horizon.max(1) as f32).sqrt(),
            LrSchedule::ExpDecay { alpha, half_every } => {
                alpha * 0.5f32.powi((epoch / half_every.max(1)) as i32)
            }
        }
    }
}

/// Second-moment EMA parameter θ_t.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ThetaSchedule {
    /// θ_t = θ (the paper's experimental choice, θ = 0.999).
    Const { theta: f32 },
    /// θ_t = 1 - θ/t — Assumption 4.
    Anneal { theta: f32 },
    /// θ_t = 1 - θ/T — the corollaries' fixed-horizon variant.
    FixedHorizon { theta: f32, horizon: u64 },
}

impl ThetaSchedule {
    pub fn at(&self, t: u64) -> f32 {
        match *self {
            ThetaSchedule::Const { theta } => theta,
            ThetaSchedule::Anneal { theta } => 1.0 - theta / t.max(1) as f32,
            ThetaSchedule::FixedHorizon { theta, horizon } => 1.0 - theta / horizon.max(1) as f32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn invsqrt_matches_assumption4() {
        let s = LrSchedule::InvSqrt { alpha: 0.1 };
        assert_eq!(s.at(1, 0), 0.1);
        assert!((s.at(4, 0) - 0.05).abs() < 1e-7);
        assert!((s.at(100, 0) - 0.01).abs() < 1e-7);
    }

    #[test]
    fn expdecay_halves() {
        let s = LrSchedule::ExpDecay { alpha: 1e-3, half_every: 50 };
        assert_eq!(s.at(1, 0), 1e-3);
        assert_eq!(s.at(1, 49), 1e-3);
        assert_eq!(s.at(1, 50), 5e-4);
        assert_eq!(s.at(1, 150), 1.25e-4);
    }

    #[test]
    fn theta_anneal() {
        let s = ThetaSchedule::Anneal { theta: 0.1 };
        assert!((s.at(1) - 0.9).abs() < 1e-7);
        assert!((s.at(10) - 0.99).abs() < 1e-7);
    }
}
