//! The worker-side optimizer contract (Alg. 3) and its implementations.

use super::adam::{AdamState, Momentum};
use super::schedule::{LrSchedule, ThetaSchedule};
use crate::quant::{
    Blockwise, CodecPolicy, Compressor, DeltaMsg, ErrorFeedback, Identity, LogQuant, TernGrad,
};
use crate::util::DetRng;

/// One worker's optimizer: consumes the local stochastic gradient at the
/// broadcast weights and emits the compressed update payload — a single
/// message on the static codec path (byte-identical to pre-policy
/// builds), one message per layout tensor under a codec policy. The
/// server applies `x <- x - mean_i decode(msg_i)`.
///
/// `Send` so a whole [`crate::ps::Worker`] can run on its own
/// [`crate::ps::transport::ThreadedBus`] thread.
pub trait WorkerOpt: Send {
    /// `t` is the 1-based global iteration; `epoch` drives ExpDecay.
    fn step(&mut self, grad: &[f32], t: u64, epoch: u64, rng: &mut DetRng) -> DeltaMsg;
    fn name(&self) -> String;
    /// Analytic uplink bits per model element (Comm column formula).
    fn bits_per_element(&self) -> f64;
    /// Residual norm (0 when EF is off) — for diagnostics.
    fn residual_norm(&self) -> f32 {
        0.0
    }
    /// Mean code bits/element the codec policy currently chooses (None
    /// on the static path).
    fn policy_bits(&self) -> Option<f64> {
        None
    }
    /// Per-tensor levels the codec policy currently chooses (None on
    /// the static path) — parity tests compare these across engines.
    fn chosen_bits(&self) -> Option<Vec<u32>> {
        None
    }
    /// Checkpointable optimizer state (m, v, e), when the optimizer has
    /// one (QAdam family). Baselines return None (cold resume).
    fn state(&self) -> Option<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        None
    }
    /// Restore state saved by [`WorkerOpt::state`].
    fn restore(&mut self, _m: &[f32], _v: &[f32], _e: &[f32]) {}
}

// ---------------------------------------------------------------------------
// QAdam-EF — the paper's method
// ---------------------------------------------------------------------------

/// Quantized generic Adam with error feedback (Alg. 1 / Alg. 3),
/// pure-Rust fused path.
pub struct QAdamEf {
    state: AdamState,
    ef: ErrorFeedback,
    comp: Box<dyn Compressor>,
    /// Per-tensor codec policy (None = the static single-message path,
    /// byte-identical to pre-policy builds). Each worker owns its own
    /// instance: decisions are driven by its own EF state and never
    /// cross the wire except as per-part codec headers.
    policy: Option<CodecPolicy>,
    pub lr: LrSchedule,
    pub theta: ThetaSchedule,
    pub beta: f32,
    pub eps: f32,
    dir: Vec<f32>,
}

impl QAdamEf {
    pub fn new(
        dim: usize,
        comp: Box<dyn Compressor>,
        ef_enabled: bool,
        lr: LrSchedule,
        theta: ThetaSchedule,
        beta: f32,
        eps: f32,
    ) -> Self {
        Self {
            state: AdamState::new(dim),
            ef: ErrorFeedback::new(dim, ef_enabled),
            comp,
            policy: None,
            lr,
            theta,
            beta,
            eps,
            dir: vec![0.0; dim],
        }
    }

    /// Install a per-tensor codec policy (builder style). A static spec
    /// installs nothing — the single-message path stays byte-identical,
    /// asserted in `rust/tests/policy_parity.rs`. The policy layout dim
    /// must equal the model dim.
    pub fn with_policy(mut self, policy: CodecPolicy) -> Self {
        assert_eq!(
            policy.layout().dim(),
            self.dir.len(),
            "policy layout dim != model dim"
        );
        if !policy.spec().is_static() {
            self.policy = Some(policy);
        }
        self
    }

    /// Paper defaults: LogQuant(kg), EF on, β=0.99, θ=0.999, ε=1e-5.
    pub fn paper_default(dim: usize, kg: u32, lr: LrSchedule) -> Self {
        Self::new(
            dim,
            Box::new(LogQuant::new(kg)),
            true,
            lr,
            ThetaSchedule::Const { theta: crate::defaults::THETA },
            crate::defaults::BETA,
            crate::defaults::EPS,
        )
    }

    /// Full-precision distributed Adam (Identity codec): the fp32 rows.
    pub fn full_precision(dim: usize, lr: LrSchedule) -> Self {
        Self::new(
            dim,
            Box::new(Identity),
            false,
            lr,
            ThetaSchedule::Const { theta: crate::defaults::THETA },
            crate::defaults::BETA,
            crate::defaults::EPS,
        )
    }
}

impl WorkerOpt for QAdamEf {
    fn step(&mut self, grad: &[f32], t: u64, epoch: u64, rng: &mut DetRng) -> DeltaMsg {
        let alpha = self.lr.at(t, epoch);
        let theta = self.theta.at(t);
        let mut dir = std::mem::take(&mut self.dir);
        self.state.step_into(grad, alpha, self.beta, theta, self.eps, &mut dir);
        let out = match self.policy.as_mut() {
            None => DeltaMsg::Single(self.ef.compress(&dir, self.comp.as_ref(), rng)),
            Some(policy) => {
                // Decide the per-tensor levels from the debt the last
                // round's codec left behind, then run the range-EF step
                // one tensor at a time (each part gets its own ∞-norm
                // scale and codec header).
                policy.decide(t, &dir, self.ef.residual());
                let mut parts = Vec::with_capacity(policy.layout().tensors().len());
                for (i, ts) in policy.layout().tensors().iter().enumerate() {
                    let comp = LogQuant::new(policy.bits()[i]);
                    parts.push(self.ef.compress_range(&dir, ts.start, ts.len, &comp, rng));
                }
                DeltaMsg::Parts(parts)
            }
        };
        self.dir = dir;
        out
    }

    fn name(&self) -> String {
        match &self.policy {
            Some(p) => format!(
                "qadam[{}{}+{}]",
                self.comp.name(),
                if self.ef.enabled() { "+ef" } else { "" },
                p.spec().label()
            ),
            None => {
                format!("qadam[{}{}]", self.comp.name(), if self.ef.enabled() { "+ef" } else { "" })
            }
        }
    }

    fn bits_per_element(&self) -> f64 {
        match &self.policy {
            Some(p) => p.mean_code_bits(),
            None => self.comp.bits_per_element(),
        }
    }

    fn residual_norm(&self) -> f32 {
        self.ef.residual_norm()
    }

    fn policy_bits(&self) -> Option<f64> {
        self.policy.as_ref().map(|p| p.mean_code_bits())
    }

    fn chosen_bits(&self) -> Option<Vec<u32>> {
        self.policy.as_ref().map(|p| p.bits().to_vec())
    }

    fn state(&self) -> Option<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        Some((self.state.m.clone(), self.state.v.clone(), self.ef.residual().to_vec()))
    }

    fn restore(&mut self, m: &[f32], v: &[f32], e: &[f32]) {
        self.state.set(m, v);
        self.ef.set_residual(e);
    }
}

// ---------------------------------------------------------------------------
// TernGrad baseline (Wen et al. [39])
// ---------------------------------------------------------------------------

/// TernGrad: workers send the unbiased stochastic ternary quantization
/// of `lr_t * g`; no momentum, no error feedback (base algorithm).
pub struct TernGradSgd {
    comp: TernGrad,
    pub lr: LrSchedule,
    scaled: Vec<f32>,
    q: Vec<f32>,
}

impl TernGradSgd {
    pub fn new(dim: usize, lr: LrSchedule) -> Self {
        Self { comp: TernGrad, lr, scaled: vec![0.0; dim], q: vec![0.0; dim] }
    }
}

impl WorkerOpt for TernGradSgd {
    fn step(&mut self, grad: &[f32], t: u64, epoch: u64, rng: &mut DetRng) -> DeltaMsg {
        let lr = self.lr.at(t, epoch);
        for (s, &g) in self.scaled.iter_mut().zip(grad) {
            *s = lr * g;
        }
        DeltaMsg::Single(self.comp.compress_into(&self.scaled, &mut self.q, rng))
    }

    fn name(&self) -> String {
        "terngrad".into()
    }

    fn bits_per_element(&self) -> f64 {
        self.comp.bits_per_element()
    }
}

// ---------------------------------------------------------------------------
// Blockwise momentum SGD with EF (Zheng et al. [44])
// ---------------------------------------------------------------------------

/// Zheng et al.: momentum-SGD update, blockwise sign compression,
/// error feedback.
pub struct BlockwiseSgdEf {
    mom: Momentum,
    ef: ErrorFeedback,
    comp: Blockwise,
    pub lr: LrSchedule,
    dir: Vec<f32>,
}

impl BlockwiseSgdEf {
    pub fn new(dim: usize, mu: f32, block: usize, lr: LrSchedule) -> Self {
        Self {
            mom: Momentum::new(dim, mu),
            ef: ErrorFeedback::new(dim, true),
            comp: Blockwise::new(block),
            lr,
            dir: vec![0.0; dim],
        }
    }
}

impl WorkerOpt for BlockwiseSgdEf {
    fn step(&mut self, grad: &[f32], t: u64, epoch: u64, rng: &mut DetRng) -> DeltaMsg {
        let lr = self.lr.at(t, epoch);
        let mut dir = std::mem::take(&mut self.dir);
        self.mom.step_into(grad, lr, &mut dir);
        let msg = self.ef.compress(&dir, &self.comp, rng);
        self.dir = dir;
        DeltaMsg::Single(msg)
    }

    fn name(&self) -> String {
        "blockwise-ef".into()
    }

    fn bits_per_element(&self) -> f64 {
        self.comp.bits_per_element()
    }

    fn residual_norm(&self) -> f32 {
        self.ef.residual_norm()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::seeded_rng;

    fn quad_grad(x: &[f32]) -> Vec<f32> {
        // f(x) = 0.5 ||x - 1||^2
        x.iter().map(|&xi| xi - 1.0).collect()
    }

    fn run_opt(mut opt: Box<dyn WorkerOpt>, steps: u64) -> f32 {
        // single-worker descent loop: x -= decode(msg)
        let dim = 16;
        let mut x = vec![0.0f32; dim];
        let mut rng = seeded_rng(0, 0);
        for t in 1..=steps {
            let g = quad_grad(&x);
            let msg = opt.step(&g, t, 0, &mut rng);
            let mut delta = vec![0.0; dim];
            msg.decode(&mut delta);
            for (xi, d) in x.iter_mut().zip(&delta) {
                *xi -= d;
            }
        }
        // final distance to optimum
        x.iter().map(|&xi| (xi - 1.0) * (xi - 1.0)).sum::<f32>().sqrt()
    }

    #[test]
    fn qadam_ef_converges_on_quadratic() {
        // InvSqrt decay (Assumption 4) so the constant-step oscillation
        // floor shrinks with t.
        let opt = QAdamEf::paper_default(16, 2, LrSchedule::InvSqrt { alpha: 0.3 });
        let d = run_opt(Box::new(opt), 800);
        assert!(d < 0.2, "dist={d}");
    }

    #[test]
    fn full_precision_adam_converges() {
        let opt = QAdamEf::full_precision(16, LrSchedule::InvSqrt { alpha: 0.3 });
        let d = run_opt(Box::new(opt), 800);
        assert!(d < 0.15, "dist={d}");
    }

    #[test]
    fn terngrad_converges_on_quadratic() {
        let opt = TernGradSgd::new(16, LrSchedule::InvSqrt { alpha: 0.3 });
        let d = run_opt(Box::new(opt), 800);
        assert!(d < 0.3, "dist={d}");
    }

    #[test]
    fn blockwise_converges_on_quadratic() {
        let opt = BlockwiseSgdEf::new(16, 0.9, 8, LrSchedule::InvSqrt { alpha: 0.05 });
        let d = run_opt(Box::new(opt), 800);
        assert!(d < 0.3, "dist={d}");
    }

    /// The adaptive policy path still converges on the quadratic (the
    /// controller moves bits, never the semantics), reports its chosen
    /// levels, and a static-spec policy is a byte-identical no-op.
    #[test]
    fn qadam_policy_paths() {
        use crate::quant::{CodecPolicy, PolicySpec, TensorLayout};
        let dim = 16;
        let layout = TensorLayout::uniform(dim, 4);
        let mk = |spec: PolicySpec| -> QAdamEf {
            QAdamEf::paper_default(dim, 2, LrSchedule::InvSqrt { alpha: 0.3 })
                .with_policy(CodecPolicy::new(spec, layout.clone(), 2).unwrap())
        };
        // adaptive: converges, stays in band, reports parts
        let mut opt = mk(PolicySpec::Adaptive { lo: 0, hi: 4 });
        assert!(opt.chosen_bits().is_some());
        let d = run_opt(Box::new(mk(PolicySpec::Adaptive { lo: 0, hi: 4 })), 800);
        assert!(d < 0.3, "dist={d}");
        let mut rng = seeded_rng(0, 0);
        let origin = vec![0.0f32; dim];
        let msg = opt.step(&quad_grad(&origin), 1, 0, &mut rng);
        assert!(matches!(&msg, crate::quant::DeltaMsg::Parts(p) if p.len() == 4));
        assert!(opt.chosen_bits().unwrap().iter().all(|&b| b <= 4));
        assert!(opt.policy_bits().unwrap() >= 2.0, "code bits of kg>=0 are >= 2");
        // static spec: bit-identical to no policy at all
        let mut plain = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.1 });
        let mut static_pol = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.1 })
            .with_policy(CodecPolicy::new(PolicySpec::Static, layout, 2).unwrap());
        assert!(static_pol.chosen_bits().is_none());
        let mut rng_a = seeded_rng(1, 1);
        let mut rng_b = seeded_rng(1, 1);
        for t in 1..=20 {
            let g: Vec<f32> = (0..dim).map(|i| ((t as f32 + i as f32) * 0.3).sin()).collect();
            let a = plain.step(&g, t, 0, &mut rng_a);
            let b = static_pol.step(&g, t, 0, &mut rng_b);
            match (a, b) {
                (crate::quant::DeltaMsg::Single(ma), crate::quant::DeltaMsg::Single(mb)) => {
                    assert_eq!(ma.to_bytes(), mb.to_bytes(), "t={t}");
                }
                other => panic!("static path must stay single-message: {other:?}"),
            }
        }
    }

    #[test]
    fn ef_residual_bounded_lemma_4_5() {
        // Lemma 4.5's mechanism: ||e_t|| <= sum_i (1-delta)^(t-i+1) ||D_i||
        // <= ((1-delta)/delta) max||D_i||, and ||D_t|| <= alpha_t sqrt(d)
        // (since |m/sqrt(v+eps)| <= 1/sqrt(1-theta) is bounded). With a
        // constant alpha the residual must stay bounded over time; with
        // InvSqrt alpha it must shrink.
        let run = |lr: LrSchedule, steps: u64| -> (f32, f32) {
            let mut opt = QAdamEf::new(
                16,
                Box::new(LogQuant::new(0)),
                true,
                lr,
                ThetaSchedule::Const { theta: 0.999 },
                0.9,
                1e-8,
            );
            let mut rng = seeded_rng(0, 0);
            let mut mid = 0.0f32;
            for t in 1..=steps {
                // adversarial-ish heterogeneous gradients
                let g: Vec<f32> = (0..16)
                    .map(|i| ((t as f32 * 0.37 + i as f32).sin()) * (0.01 + i as f32 * 0.1))
                    .collect();
                opt.step(&g, t, 0, &mut rng);
                if t == steps / 2 {
                    mid = opt.residual_norm();
                }
            }
            (mid, opt.residual_norm())
        };
        // constant alpha: bounded (end not much above mid)
        let (mid_c, end_c) = run(LrSchedule::Const { alpha: 0.1 }, 1000);
        assert!(end_c < 4.0 * mid_c.max(0.05), "const-alpha residual grew: mid={mid_c} end={end_c}");
        // Cap from the lemma: ((1-delta)/delta) * max||D|| with delta >=
        // 2^-(kg+2)=0.25 and ||D|| <= alpha*sqrt(d)*C; generous constant.
        assert!(end_c <= 0.1 * 4.0 * 3.0 * 4.0, "end={end_c}");
        // decaying alpha: residual shrinks with the step size
        let (_, end_d) = run(LrSchedule::InvSqrt { alpha: 0.1 }, 1000);
        assert!(end_d < end_c, "decayed residual {end_d} should be below constant-alpha {end_c}");
    }
}
