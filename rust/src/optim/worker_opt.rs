//! The worker-side optimizer contract (Alg. 3) and its implementations.

use super::adam::{AdamState, Momentum};
use super::schedule::{LrSchedule, ThetaSchedule};
use crate::quant::{
    Blockwise, CodecPolicy, Compressor, DeltaMsg, ErrorFeedback, Identity, LogQuant, TernGrad,
};
use crate::util::DetRng;
use anyhow::{anyhow, Result};

/// Is `ranges` the trivial single full-vector range? (The sharded step
/// then delegates to the classic [`WorkerOpt::step`], byte-identically.)
fn is_single_full_range(ranges: &[(usize, usize)], dim: usize) -> bool {
    matches!(ranges, [(0, len)] if *len == dim)
}

/// One worker's optimizer: consumes the local stochastic gradient at the
/// broadcast weights and emits the compressed update payload — a single
/// message on the static codec path (byte-identical to pre-policy
/// builds), one message per layout tensor under a codec policy. The
/// server applies `x <- x - mean_i decode(msg_i)`.
///
/// `Send` so a whole [`crate::ps::Worker`] can run on its own
/// [`crate::ps::transport::ThreadedBus`] thread.
pub trait WorkerOpt: Send {
    /// `t` is the 1-based global iteration; `epoch` drives ExpDecay.
    fn step(&mut self, grad: &[f32], t: u64, epoch: u64, rng: &mut DetRng) -> DeltaMsg;
    /// Sharded step: one [`DeltaMsg`] per contiguous `(start, len)`
    /// range of `ranges` (ascending, tiling the vector), in range
    /// order. The *optimizer state* (moments, EF residual) stays
    /// global and advances exactly once — only the compression is run
    /// per range, each range getting its own codec scale, so the wire
    /// payload can be routed to N independent parameter-server shards.
    ///
    /// The default handles the single full-vector range by delegating
    /// to [`WorkerOpt::step`] (byte-identical to the unsharded path)
    /// and rejects true multi-range plans — optimizers that can split
    /// their payload (the native QAdam family and the baselines)
    /// override it; the AOT kernel path cannot (its compression is
    /// fused) and is rejected at config validation.
    fn step_sharded(
        &mut self,
        grad: &[f32],
        t: u64,
        epoch: u64,
        rng: &mut DetRng,
        ranges: &[(usize, usize)],
    ) -> Result<Vec<DeltaMsg>> {
        if is_single_full_range(ranges, grad.len()) {
            return Ok(vec![self.step(grad, t, epoch, rng)]);
        }
        Err(anyhow!("optimizer '{}' does not support sharded stepping", self.name()))
    }
    fn name(&self) -> String;
    /// Analytic uplink bits per model element (Comm column formula).
    fn bits_per_element(&self) -> f64;
    /// Residual norm (0 when EF is off) — for diagnostics.
    fn residual_norm(&self) -> f32 {
        0.0
    }
    /// Residual ∞-norm (0 when EF is off) — the obs-layer
    /// `qadam_ef_residual_inf_norm` gauge.
    fn residual_inf_norm(&self) -> f32 {
        0.0
    }
    /// Mean code bits/element the codec policy currently chooses (None
    /// on the static path).
    fn policy_bits(&self) -> Option<f64> {
        None
    }
    /// Per-tensor levels the codec policy currently chooses (None on
    /// the static path) — parity tests compare these across engines.
    /// A borrowed view into the live policy state: copy-free in the
    /// round path; callers that need ownership (checkpoints) copy.
    fn chosen_bits(&self) -> Option<&[u32]> {
        None
    }
    /// Does this optimizer carry an error-feedback residual? Async
    /// rounds require one: a rejected (too-stale) delta's mass is
    /// refunded into the residual, and without EF there is nowhere to
    /// carry it — config validation rejects the combination.
    fn has_error_feedback(&self) -> bool {
        false
    }
    /// Fold un-applied update mass back into the EF residual over
    /// `[start, start + vals.len())`: `e[start + i] += scale * vals[i]`
    /// — the async-round refund path ([`crate::quant::ErrorFeedback::absorb_range`]).
    /// Default no-op for optimizers without a residual.
    fn absorb_residual(&mut self, _start: usize, _vals: &[f32], _scale: f32) {}
    /// Checkpointable optimizer state (m, v, e), when the optimizer has
    /// one (QAdam family). Baselines return None (cold resume).
    /// Borrowed views — the checkpoint writer owns the one copy.
    fn state(&self) -> Option<(&[f32], &[f32], &[f32])> {
        None
    }
    /// Restore state saved by [`WorkerOpt::state`].
    fn restore(&mut self, _m: &[f32], _v: &[f32], _e: &[f32]) {}
}

// ---------------------------------------------------------------------------
// QAdam-EF — the paper's method
// ---------------------------------------------------------------------------

/// Quantized generic Adam with error feedback (Alg. 1 / Alg. 3),
/// pure-Rust fused path.
pub struct QAdamEf {
    state: AdamState,
    ef: ErrorFeedback,
    comp: Box<dyn Compressor>,
    /// Per-tensor codec policy (None = the static single-message path,
    /// byte-identical to pre-policy builds). Each worker owns its own
    /// instance: decisions are driven by its own EF state and never
    /// cross the wire except as per-part codec headers.
    policy: Option<CodecPolicy>,
    pub lr: LrSchedule,
    pub theta: ThetaSchedule,
    pub beta: f32,
    pub eps: f32,
    dir: Vec<f32>,
}

impl QAdamEf {
    pub fn new(
        dim: usize,
        comp: Box<dyn Compressor>,
        ef_enabled: bool,
        lr: LrSchedule,
        theta: ThetaSchedule,
        beta: f32,
        eps: f32,
    ) -> Self {
        Self {
            state: AdamState::new(dim),
            ef: ErrorFeedback::new(dim, ef_enabled),
            comp,
            policy: None,
            lr,
            theta,
            beta,
            eps,
            dir: vec![0.0; dim],
        }
    }

    /// Install a per-tensor codec policy (builder style). A static spec
    /// installs nothing — the single-message path stays byte-identical,
    /// asserted in `rust/tests/policy_parity.rs`. The policy layout dim
    /// must equal the model dim.
    pub fn with_policy(mut self, policy: CodecPolicy) -> Self {
        assert_eq!(
            policy.layout().dim(),
            self.dir.len(),
            "policy layout dim != model dim"
        );
        if !policy.spec().is_static() {
            self.policy = Some(policy);
        }
        self
    }

    /// Paper defaults: LogQuant(kg), EF on, β=0.99, θ=0.999, ε=1e-5.
    pub fn paper_default(dim: usize, kg: u32, lr: LrSchedule) -> Self {
        Self::new(
            dim,
            Box::new(LogQuant::new(kg)),
            true,
            lr,
            ThetaSchedule::Const { theta: crate::defaults::THETA },
            crate::defaults::BETA,
            crate::defaults::EPS,
        )
    }

    /// Full-precision distributed Adam (Identity codec): the fp32 rows.
    pub fn full_precision(dim: usize, lr: LrSchedule) -> Self {
        Self::new(
            dim,
            Box::new(Identity),
            false,
            lr,
            ThetaSchedule::Const { theta: crate::defaults::THETA },
            crate::defaults::BETA,
            crate::defaults::EPS,
        )
    }
}

impl WorkerOpt for QAdamEf {
    fn step(&mut self, grad: &[f32], t: u64, epoch: u64, rng: &mut DetRng) -> DeltaMsg {
        let alpha = self.lr.at(t, epoch);
        let theta = self.theta.at(t);
        let mut dir = std::mem::take(&mut self.dir);
        self.state.step_into(grad, alpha, self.beta, theta, self.eps, &mut dir);
        let out = match self.policy.as_mut() {
            None => DeltaMsg::Single(self.ef.compress(&dir, self.comp.as_ref(), rng)),
            Some(policy) => {
                // Decide the per-tensor levels from the debt the last
                // round's codec left behind, then run the range-EF step
                // one tensor at a time (each part gets its own ∞-norm
                // scale and codec header).
                policy.decide(t, &dir, self.ef.residual());
                let mut parts = Vec::with_capacity(policy.layout().tensors().len());
                for (i, ts) in policy.layout().tensors().iter().enumerate() {
                    let comp = policy.codec_at(i);
                    parts.push(self.ef.compress_range(&dir, ts.start, ts.len, comp.as_dyn(), rng));
                }
                DeltaMsg::Parts(parts)
            }
        };
        self.dir = dir;
        out
    }

    /// Sharded step: the Adam moments and the EF residual advance once,
    /// globally; only the compression runs per shard range (each range
    /// — or, under a policy, each tensor — gets its own scale via
    /// [`ErrorFeedback::compress_range`]). Under a codec policy the
    /// controller decides once over the full vector and the per-tensor
    /// messages are **bit-identical** to the unsharded parts — sharding
    /// only regroups them into per-shard frames.
    fn step_sharded(
        &mut self,
        grad: &[f32],
        t: u64,
        epoch: u64,
        rng: &mut DetRng,
        ranges: &[(usize, usize)],
    ) -> Result<Vec<DeltaMsg>> {
        if is_single_full_range(ranges, grad.len()) {
            return Ok(vec![self.step(grad, t, epoch, rng)]);
        }
        // Validate the plan against the layout *before* touching any
        // state: a range that splits a tensor is a deployment error.
        if let Some(policy) = &self.policy {
            let tensors = policy.layout().tensors();
            let mut ti = 0usize;
            for &(start, len) in ranges {
                let mut covered = 0usize;
                while ti < tensors.len() && covered < len {
                    let ts = &tensors[ti];
                    if ts.start != start + covered || ts.start + ts.len > start + len {
                        return Err(anyhow!(
                            "shard range {start}+{len} splits tensor '{}'",
                            ts.name
                        ));
                    }
                    covered += ts.len;
                    ti += 1;
                }
                if covered != len {
                    return Err(anyhow!("shard range {start}+{len} not tiled by the layout"));
                }
            }
        }
        let alpha = self.lr.at(t, epoch);
        let theta = self.theta.at(t);
        let mut dir = std::mem::take(&mut self.dir);
        self.state.step_into(grad, alpha, self.beta, theta, self.eps, &mut dir);
        let mut msgs = Vec::with_capacity(ranges.len());
        match self.policy.as_mut() {
            None => {
                for &(start, len) in ranges {
                    msgs.push(DeltaMsg::Single(self.ef.compress_range(
                        &dir,
                        start,
                        len,
                        self.comp.as_ref(),
                        rng,
                    )));
                }
            }
            Some(policy) => {
                // One controller decision over the full vector, then
                // the per-tensor range-EF steps in global tensor order
                // (the unsharded order), grouped into per-shard frames.
                policy.decide(t, &dir, self.ef.residual());
                let mut ti = 0usize;
                for &(start, len) in ranges {
                    let mut parts = Vec::new();
                    let mut covered = 0usize;
                    while covered < len {
                        let ts = &policy.layout().tensors()[ti];
                        let comp = policy.codec_at(ti);
                        parts.push(self.ef.compress_range(&dir, ts.start, ts.len, comp.as_dyn(), rng));
                        covered += ts.len;
                        ti += 1;
                    }
                    debug_assert_eq!(covered, len, "validated above");
                    msgs.push(DeltaMsg::Parts(parts));
                }
            }
        }
        self.dir = dir;
        Ok(msgs)
    }

    fn name(&self) -> String {
        match &self.policy {
            Some(p) => format!(
                "qadam[{}{}+{}]",
                self.comp.name(),
                if self.ef.enabled() { "+ef" } else { "" },
                p.spec().label()
            ),
            None => {
                format!("qadam[{}{}]", self.comp.name(), if self.ef.enabled() { "+ef" } else { "" })
            }
        }
    }

    fn bits_per_element(&self) -> f64 {
        match &self.policy {
            Some(p) => p.mean_code_bits(),
            None => self.comp.bits_per_element(),
        }
    }

    fn residual_norm(&self) -> f32 {
        self.ef.residual_norm()
    }

    fn residual_inf_norm(&self) -> f32 {
        self.ef.residual_inf_norm()
    }

    fn policy_bits(&self) -> Option<f64> {
        self.policy.as_ref().map(|p| p.mean_code_bits())
    }

    fn chosen_bits(&self) -> Option<&[u32]> {
        self.policy.as_ref().map(|p| p.bits())
    }

    fn has_error_feedback(&self) -> bool {
        self.ef.enabled()
    }

    fn absorb_residual(&mut self, start: usize, vals: &[f32], scale: f32) {
        self.ef.absorb_range(start, vals, scale);
    }

    fn state(&self) -> Option<(&[f32], &[f32], &[f32])> {
        Some((&self.state.m, &self.state.v, self.ef.residual()))
    }

    fn restore(&mut self, m: &[f32], v: &[f32], e: &[f32]) {
        self.state.set(m, v);
        self.ef.set_residual(e);
    }
}

// ---------------------------------------------------------------------------
// TernGrad baseline (Wen et al. [39])
// ---------------------------------------------------------------------------

/// TernGrad: workers send the unbiased stochastic ternary quantization
/// of `lr_t * g`; no momentum, no error feedback (base algorithm).
pub struct TernGradSgd {
    comp: TernGrad,
    pub lr: LrSchedule,
    scaled: Vec<f32>,
    q: Vec<f32>,
}

impl TernGradSgd {
    pub fn new(dim: usize, lr: LrSchedule) -> Self {
        Self { comp: TernGrad, lr, scaled: vec![0.0; dim], q: vec![0.0; dim] }
    }
}

impl WorkerOpt for TernGradSgd {
    fn step(&mut self, grad: &[f32], t: u64, epoch: u64, rng: &mut DetRng) -> DeltaMsg {
        let lr = self.lr.at(t, epoch);
        for (s, &g) in self.scaled.iter_mut().zip(grad) {
            *s = lr * g;
        }
        DeltaMsg::Single(self.comp.compress_into(&self.scaled, &mut self.q, rng))
    }

    /// Sharded step: the scaled gradient is computed once; each range
    /// compresses independently (its own ternary scale).
    fn step_sharded(
        &mut self,
        grad: &[f32],
        t: u64,
        epoch: u64,
        rng: &mut DetRng,
        ranges: &[(usize, usize)],
    ) -> Result<Vec<DeltaMsg>> {
        if is_single_full_range(ranges, grad.len()) {
            return Ok(vec![self.step(grad, t, epoch, rng)]);
        }
        let lr = self.lr.at(t, epoch);
        for (s, &g) in self.scaled.iter_mut().zip(grad) {
            *s = lr * g;
        }
        let mut msgs = Vec::with_capacity(ranges.len());
        for &(start, len) in ranges {
            msgs.push(DeltaMsg::Single(self.comp.compress_into(
                &self.scaled[start..start + len],
                &mut self.q[start..start + len],
                rng,
            )));
        }
        Ok(msgs)
    }

    fn name(&self) -> String {
        "terngrad".into()
    }

    fn bits_per_element(&self) -> f64 {
        self.comp.bits_per_element()
    }
}

// ---------------------------------------------------------------------------
// Blockwise momentum SGD with EF (Zheng et al. [44])
// ---------------------------------------------------------------------------

/// Zheng et al.: momentum-SGD update, blockwise sign compression,
/// error feedback.
pub struct BlockwiseSgdEf {
    mom: Momentum,
    ef: ErrorFeedback,
    comp: Blockwise,
    pub lr: LrSchedule,
    dir: Vec<f32>,
}

impl BlockwiseSgdEf {
    pub fn new(dim: usize, mu: f32, block: usize, lr: LrSchedule) -> Self {
        Self {
            mom: Momentum::new(dim, mu),
            ef: ErrorFeedback::new(dim, true),
            comp: Blockwise::new(block),
            lr,
            dir: vec![0.0; dim],
        }
    }
}

impl WorkerOpt for BlockwiseSgdEf {
    fn step(&mut self, grad: &[f32], t: u64, epoch: u64, rng: &mut DetRng) -> DeltaMsg {
        let lr = self.lr.at(t, epoch);
        let mut dir = std::mem::take(&mut self.dir);
        self.mom.step_into(grad, lr, &mut dir);
        let msg = self.ef.compress(&dir, &self.comp, rng);
        self.dir = dir;
        DeltaMsg::Single(msg)
    }

    /// Sharded step: momentum advances once, globally; each range runs
    /// the range-EF compression with its own blockwise layout (blocks
    /// restart at the range start).
    fn step_sharded(
        &mut self,
        grad: &[f32],
        t: u64,
        epoch: u64,
        rng: &mut DetRng,
        ranges: &[(usize, usize)],
    ) -> Result<Vec<DeltaMsg>> {
        if is_single_full_range(ranges, grad.len()) {
            return Ok(vec![self.step(grad, t, epoch, rng)]);
        }
        let lr = self.lr.at(t, epoch);
        let mut dir = std::mem::take(&mut self.dir);
        self.mom.step_into(grad, lr, &mut dir);
        let mut msgs = Vec::with_capacity(ranges.len());
        for &(start, len) in ranges {
            msgs.push(DeltaMsg::Single(self.ef.compress_range(&dir, start, len, &self.comp, rng)));
        }
        self.dir = dir;
        Ok(msgs)
    }

    fn name(&self) -> String {
        "blockwise-ef".into()
    }

    fn bits_per_element(&self) -> f64 {
        self.comp.bits_per_element()
    }

    fn residual_norm(&self) -> f32 {
        self.ef.residual_norm()
    }

    fn residual_inf_norm(&self) -> f32 {
        self.ef.residual_inf_norm()
    }

    fn has_error_feedback(&self) -> bool {
        self.ef.enabled()
    }

    fn absorb_residual(&mut self, start: usize, vals: &[f32], scale: f32) {
        self.ef.absorb_range(start, vals, scale);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::seeded_rng;

    fn quad_grad(x: &[f32]) -> Vec<f32> {
        // f(x) = 0.5 ||x - 1||^2
        x.iter().map(|&xi| xi - 1.0).collect()
    }

    fn run_opt(mut opt: Box<dyn WorkerOpt>, steps: u64) -> f32 {
        // single-worker descent loop: x -= decode(msg)
        let dim = 16;
        let mut x = vec![0.0f32; dim];
        let mut rng = seeded_rng(0, 0);
        for t in 1..=steps {
            let g = quad_grad(&x);
            let msg = opt.step(&g, t, 0, &mut rng);
            let mut delta = vec![0.0; dim];
            msg.decode(&mut delta);
            for (xi, d) in x.iter_mut().zip(&delta) {
                *xi -= d;
            }
        }
        // final distance to optimum
        x.iter().map(|&xi| (xi - 1.0) * (xi - 1.0)).sum::<f32>().sqrt()
    }

    #[test]
    fn qadam_ef_converges_on_quadratic() {
        // InvSqrt decay (Assumption 4) so the constant-step oscillation
        // floor shrinks with t.
        let opt = QAdamEf::paper_default(16, 2, LrSchedule::InvSqrt { alpha: 0.3 });
        let d = run_opt(Box::new(opt), 800);
        assert!(d < 0.2, "dist={d}");
    }

    #[test]
    fn full_precision_adam_converges() {
        let opt = QAdamEf::full_precision(16, LrSchedule::InvSqrt { alpha: 0.3 });
        let d = run_opt(Box::new(opt), 800);
        assert!(d < 0.15, "dist={d}");
    }

    #[test]
    fn terngrad_converges_on_quadratic() {
        let opt = TernGradSgd::new(16, LrSchedule::InvSqrt { alpha: 0.3 });
        let d = run_opt(Box::new(opt), 800);
        assert!(d < 0.3, "dist={d}");
    }

    #[test]
    fn blockwise_converges_on_quadratic() {
        let opt = BlockwiseSgdEf::new(16, 0.9, 8, LrSchedule::InvSqrt { alpha: 0.05 });
        let d = run_opt(Box::new(opt), 800);
        assert!(d < 0.3, "dist={d}");
    }

    /// The adaptive policy path still converges on the quadratic (the
    /// controller moves bits, never the semantics), reports its chosen
    /// levels, and a static-spec policy is a byte-identical no-op.
    #[test]
    fn qadam_policy_paths() {
        use crate::quant::{CodecPolicy, PolicySpec, TensorLayout};
        let dim = 16;
        let layout = TensorLayout::uniform(dim, 4);
        let mk = |spec: PolicySpec| -> QAdamEf {
            QAdamEf::paper_default(dim, 2, LrSchedule::InvSqrt { alpha: 0.3 })
                .with_policy(CodecPolicy::new(spec, layout.clone(), 2).unwrap())
        };
        // adaptive: converges, stays in band, reports parts
        let mut opt = mk(PolicySpec::Adaptive { lo: 0, hi: 4 });
        assert!(opt.chosen_bits().is_some());
        let d = run_opt(Box::new(mk(PolicySpec::Adaptive { lo: 0, hi: 4 })), 800);
        assert!(d < 0.3, "dist={d}");
        let mut rng = seeded_rng(0, 0);
        let origin = vec![0.0f32; dim];
        let msg = opt.step(&quad_grad(&origin), 1, 0, &mut rng);
        assert!(matches!(&msg, crate::quant::DeltaMsg::Parts(p) if p.len() == 4));
        assert!(opt.chosen_bits().unwrap().iter().all(|&b| b <= 4));
        assert!(opt.policy_bits().unwrap() >= 2.0, "code bits of kg>=0 are >= 2");
        // static spec: bit-identical to no policy at all
        let mut plain = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.1 });
        let mut static_pol = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.1 })
            .with_policy(CodecPolicy::new(PolicySpec::Static, layout, 2).unwrap());
        assert!(static_pol.chosen_bits().is_none());
        let mut rng_a = seeded_rng(1, 1);
        let mut rng_b = seeded_rng(1, 1);
        for t in 1..=20 {
            let g: Vec<f32> = (0..dim).map(|i| ((t as f32 + i as f32) * 0.3).sin()).collect();
            let a = plain.step(&g, t, 0, &mut rng_a);
            let b = static_pol.step(&g, t, 0, &mut rng_b);
            match (a, b) {
                (crate::quant::DeltaMsg::Single(ma), crate::quant::DeltaMsg::Single(mb)) => {
                    assert_eq!(ma.to_bytes(), mb.to_bytes(), "t={t}");
                }
                other => panic!("static path must stay single-message: {other:?}"),
            }
        }
    }

    /// The trivial single-range plan delegates to the classic step —
    /// byte-identical messages and identical optimizer state.
    #[test]
    fn step_sharded_single_range_delegates_byte_identically() {
        let dim = 16;
        let mut a = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.1 });
        let mut b = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.1 });
        let mut rng_a = seeded_rng(5, 5);
        let mut rng_b = seeded_rng(5, 5);
        for t in 1u64..=10 {
            let g: Vec<f32> = (0..dim).map(|i| ((t as f32 + i as f32) * 0.3).sin()).collect();
            let ma = a.step(&g, t, 0, &mut rng_a);
            let mb = b.step_sharded(&g, t, 0, &mut rng_b, &[(0, dim)]).unwrap();
            assert_eq!(mb.len(), 1);
            match (&ma, &mb[0]) {
                (DeltaMsg::Single(x), DeltaMsg::Single(y)) => {
                    assert_eq!(x.to_bytes(), y.to_bytes(), "t={t}")
                }
                other => panic!("{other:?}"),
            }
            assert_eq!(a.residual_norm(), b.residual_norm(), "t={t}");
        }
    }

    /// Multi-range stepping: the optimizer state advances once, each
    /// range compresses with its own scale, and the concatenated decode
    /// covers the whole update (the per-range EF identity of
    /// `quant::error_feedback` composes through the optimizer).
    #[test]
    fn step_sharded_splits_the_wire_payload_per_range() {
        let dim = 16;
        let ranges = [(0usize, 10usize), (10, 6)];
        let mut opt = QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.1 });
        let mut rng = seeded_rng(7, 7);
        for t in 1u64..=5 {
            let g: Vec<f32> = (0..dim).map(|i| ((t as f32 + i as f32) * 0.4).cos()).collect();
            let msgs = opt.step_sharded(&g, t, 0, &mut rng, &ranges).unwrap();
            assert_eq!(msgs.len(), 2);
            assert_eq!(msgs[0].n(), 10);
            assert_eq!(msgs[1].n(), 6);
        }
        assert!(opt.residual_norm() > 0.0, "the global EF state must have advanced");
    }

    /// Under a codec policy the sharded step emits per-tensor messages
    /// bit-identical to the unsharded parts — sharding only regroups
    /// them into per-shard frames — and a range that splits a tensor is
    /// rejected before any state moves.
    #[test]
    fn step_sharded_policy_parts_regroup_bit_identically() {
        use crate::quant::{CodecPolicy, PolicySpec, TensorLayout};
        let dim = 16;
        let layout = TensorLayout::uniform(dim, 4); // tensors of 4
        let mk = || {
            QAdamEf::paper_default(dim, 2, LrSchedule::Const { alpha: 0.1 }).with_policy(
                CodecPolicy::new(PolicySpec::Adaptive { lo: 0, hi: 4 }, layout.clone(), 2)
                    .unwrap(),
            )
        };
        let mut flat = mk();
        let mut sharded = mk();
        let mut rng_a = seeded_rng(3, 3);
        let mut rng_b = seeded_rng(3, 3);
        for t in 1u64..=8 {
            let g: Vec<f32> = (0..dim).map(|i| ((t as f32 * 0.7 + i as f32) * 0.5).sin()).collect();
            let ma = flat.step(&g, t, 0, &mut rng_a);
            let mb = sharded.step_sharded(&g, t, 0, &mut rng_b, &[(0, 8), (8, 8)]).unwrap();
            let flat_parts = match &ma {
                DeltaMsg::Parts(p) => p.clone(),
                other => panic!("{other:?}"),
            };
            let sharded_parts: Vec<_> = mb
                .iter()
                .flat_map(|m| match m {
                    DeltaMsg::Parts(p) => p.clone(),
                    other => panic!("{other:?}"),
                })
                .collect();
            assert_eq!(flat_parts.len(), sharded_parts.len(), "t={t}");
            for (x, y) in flat_parts.iter().zip(&sharded_parts) {
                assert_eq!(x.to_bytes(), y.to_bytes(), "t={t}");
            }
            assert_eq!(flat.chosen_bits(), sharded.chosen_bits(), "t={t}");
        }
        // a plan that splits a tensor is a clean error, not a panic
        let g = vec![0.1f32; dim];
        let err = mk().step_sharded(&g, 1, 0, &mut seeded_rng(0, 0), &[(0, 6), (6, 10)]);
        assert!(err.is_err());
        // the default impl rejects multi-range plans for optimizers
        // that cannot split (exercised via a minimal shim)
        struct NoSplit;
        impl WorkerOpt for NoSplit {
            fn step(&mut self, g: &[f32], _t: u64, _e: u64, rng: &mut DetRng) -> DeltaMsg {
                let mut q = vec![0.0; g.len()];
                DeltaMsg::Single(Identity.compress_into(g, &mut q, rng))
            }
            fn name(&self) -> String {
                "nosplit".into()
            }
            fn bits_per_element(&self) -> f64 {
                32.0
            }
        }
        let mut ns = NoSplit;
        assert!(ns.step_sharded(&[0.0; 8], 1, 0, &mut seeded_rng(0, 0), &[(0, 8)]).is_ok());
        assert!(ns
            .step_sharded(&[0.0; 8], 1, 0, &mut seeded_rng(0, 0), &[(0, 4), (4, 4)])
            .is_err());
    }

    #[test]
    fn ef_residual_bounded_lemma_4_5() {
        // Lemma 4.5's mechanism: ||e_t|| <= sum_i (1-delta)^(t-i+1) ||D_i||
        // <= ((1-delta)/delta) max||D_i||, and ||D_t|| <= alpha_t sqrt(d)
        // (since |m/sqrt(v+eps)| <= 1/sqrt(1-theta) is bounded). With a
        // constant alpha the residual must stay bounded over time; with
        // InvSqrt alpha it must shrink.
        let run = |lr: LrSchedule, steps: u64| -> (f32, f32) {
            let mut opt = QAdamEf::new(
                16,
                Box::new(LogQuant::new(0)),
                true,
                lr,
                ThetaSchedule::Const { theta: 0.999 },
                0.9,
                1e-8,
            );
            let mut rng = seeded_rng(0, 0);
            let mut mid = 0.0f32;
            for t in 1..=steps {
                // adversarial-ish heterogeneous gradients
                let g: Vec<f32> = (0..16)
                    .map(|i| ((t as f32 * 0.37 + i as f32).sin()) * (0.01 + i as f32 * 0.1))
                    .collect();
                opt.step(&g, t, 0, &mut rng);
                if t == steps / 2 {
                    mid = opt.residual_norm();
                }
            }
            (mid, opt.residual_norm())
        };
        // constant alpha: bounded (end not much above mid)
        let (mid_c, end_c) = run(LrSchedule::Const { alpha: 0.1 }, 1000);
        assert!(end_c < 4.0 * mid_c.max(0.05), "const-alpha residual grew: mid={mid_c} end={end_c}");
        // Cap from the lemma: ((1-delta)/delta) * max||D|| with delta >=
        // 2^-(kg+2)=0.25 and ||D|| <= alpha*sqrt(d)*C; generous constant.
        assert!(end_c <= 0.1 * 4.0 * 3.0 * 4.0, "end={end_c}");
        // decaying alpha: residual shrinks with the step size
        let (_, end_d) = run(LrSchedule::InvSqrt { alpha: 0.1 }, 1000);
        assert!(end_d < end_c, "decayed residual {end_d} should be below constant-alpha {end_c}");
    }
}
