//! Generic-Adam moment state and the fused native step (Alg. 1 lines
//! 3–5). This is the pure-Rust mirror of the Pallas kernel in
//! `python/compile/kernels/qadam.py`; the integration tests assert the
//! two produce the same numbers through the PJRT runtime.

/// First/second moment buffers of one worker.
#[derive(Clone, Debug)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

impl AdamState {
    pub fn new(dim: usize) -> Self {
        Self { m: vec![0.0; dim], v: vec![0.0; dim] }
    }

    pub fn dim(&self) -> usize {
        self.m.len()
    }

    /// Fused moment update + update direction:
    ///
    /// ```text
    ///   m <- beta*m + (1-beta) g
    ///   v <- theta*v + (1-theta) g^2
    ///   dir_i = alpha * m_i / sqrt(v_i + eps)
    /// ```
    ///
    /// Single pass, no allocation — the worker hot loop.
    pub fn step_into(
        &mut self,
        g: &[f32],
        alpha: f32,
        beta: f32,
        theta: f32,
        eps: f32,
        dir: &mut [f32],
    ) {
        assert_eq!(g.len(), self.m.len());
        assert_eq!(dir.len(), self.m.len());
        let (b1, b2) = (1.0 - beta, 1.0 - theta);
        for i in 0..g.len() {
            let gi = g[i];
            let m = beta * self.m[i] + b1 * gi;
            let v = theta * self.v[i] + b2 * gi * gi;
            self.m[i] = m;
            self.v[i] = v;
            dir[i] = alpha * m / (v + eps).sqrt();
        }
    }

    /// Overwrite the moments (used by the PJRT path, where the Pallas
    /// kernel owns the recursion).
    pub fn set(&mut self, m: &[f32], v: &[f32]) {
        self.m.copy_from_slice(m);
        self.v.copy_from_slice(v);
    }
}

/// Plain momentum buffer for the SGD baselines: `p <- mu*p + g`.
#[derive(Clone, Debug)]
pub struct Momentum {
    pub p: Vec<f32>,
    pub mu: f32,
}

impl Momentum {
    pub fn new(dim: usize, mu: f32) -> Self {
        Self { p: vec![0.0; dim], mu }
    }

    pub fn step_into(&mut self, g: &[f32], lr: f32, dir: &mut [f32]) {
        for i in 0..g.len() {
            let p = self.mu * self.p[i] + g[i];
            self.p[i] = p;
            dir[i] = lr * p;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_scalar_recursion() {
        let mut st = AdamState::new(3);
        let g1 = [1.0f32, -2.0, 0.5];
        let g2 = [0.5f32, 1.0, -0.25];
        let (alpha, beta, theta, eps) = (0.1, 0.9, 0.99, 1e-8);
        let mut dir = vec![0.0; 3];
        st.step_into(&g1, alpha, beta, theta, eps, &mut dir);
        // t=1: m = 0.1*g, v = 0.01*g^2
        for i in 0..3 {
            let m = 0.1 * g1[i];
            let v = 0.01 * g1[i] * g1[i];
            assert!((st.m[i] - m).abs() < 1e-7);
            assert!((st.v[i] - v).abs() < 1e-7);
            assert!((dir[i] - alpha * m / (v + eps).sqrt()).abs() < 1e-6);
        }
        st.step_into(&g2, alpha, beta, theta, eps, &mut dir);
        for i in 0..3 {
            let m = 0.9 * (0.1 * g1[i]) + 0.1 * g2[i];
            assert!((st.m[i] - m).abs() < 1e-6);
        }
    }

    #[test]
    fn zero_gradient_decays_direction() {
        let mut st = AdamState::new(1);
        let mut dir = vec![0.0; 1];
        st.step_into(&[1.0], 0.1, 0.9, 0.99, 1e-8, &mut dir);
        let d1 = dir[0].abs();
        for _ in 0..50 {
            st.step_into(&[0.0], 0.1, 0.9, 0.99, 1e-8, &mut dir);
        }
        assert!(dir[0].abs() < 0.1 * d1);
    }

    #[test]
    fn momentum_accumulates() {
        let mut mo = Momentum::new(1, 0.9);
        let mut dir = vec![0.0; 1];
        for _ in 0..200 {
            mo.step_into(&[1.0], 1.0, &mut dir);
        }
        // geometric limit 1/(1-0.9) = 10
        assert!((dir[0] - 10.0).abs() < 0.1);
    }
}
