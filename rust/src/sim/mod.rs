//! Synthetic stochastic nonconvex problems for the convergence-theory
//! checks (Theorems 3.1–3.3, exercised by `examples/convergence_check`
//! and the integration tests).
//!
//! The objective is a separable smooth nonconvex function with bounded
//! gradients — it satisfies Assumption 1 by construction:
//!
//! ```text
//!   f(x) = (1/d) Σ_j [ x_j^2 / (1 + x_j^2) + c · (1 - cos(x_j)) ]
//! ```
//!
//! * gradient Lipschitz (both terms have bounded second derivative),
//! * ‖∇f‖ bounded (so G exists),
//! * nonconvex (saddles/plateaus from both terms),
//! * unique global minimum at 0 — which makes "distance to stationarity"
//!   measurable in closed form.
//!
//! Stochastic gradients add bounded zero-mean noise, matching the
//! unbiased + bounded-norm part of Assumption 1.
//!
//! # Which theorem each convergence check exercises
//!
//! The checks live in `rust/tests/convergence_theory.rs` (tier-1) and
//! `examples/convergence_check.rs` (the printed sweep); both drive
//! Algorithms 2–3 end-to-end over this problem and measure the tail of
//! `‖∇f‖²` — at the *quantized* weights `Q_x(x_t)` when weight
//! quantization is on, which is the quantity Theorems 3.2–3.3 bound.
//!
//! * **Theorem 3.1** (gradient quantization + error feedback,
//!   single worker): `min_t E‖∇f(x_t)‖²` decays toward 0 at the
//!   `O(1/√T)` rate — checked by running `Q_g` (k_g = 2) with EF and
//!   asserting the tail gradient is tiny and within a constant of the
//!   fp32 run. The biased-compressor contraction it needs
//!   (Assumption 2, `δ_g = 2^-(k_g+2)`) is itself property-tested in
//!   [`crate::quant::logquant`].
//! * **Theorem 3.2** (weight quantization, single worker): with `Q_x`
//!   the iterates converge only to a **floor** `C₇ ∝ δ_x` set by the
//!   weight-grid resolution. [`StochasticProblem::with_offgrid_minimum`]
//!   exists precisely for this check: a minimizer sitting *on* the
//!   dyadic `Q_x` grid would hide the floor, so the check plants it
//!   off-grid and asserts the plateau shrinks as `k_x` grows
//!   (see [`crate::quant::wquant`] for `δ_x = 2^-(k_x+2)`).
//! * **Theorem 3.3** (multi-worker, both quantizers): the same
//!   guarantees survive averaging over `M` workers — checked by running
//!   1 vs 8 workers and asserting more workers do not hurt the tail
//!   gradient (noise averaging may only help).

#[derive(Clone, Debug)]
pub struct StochasticProblem {
    pub dim: usize,
    /// uniform noise half-width per coordinate.
    pub sigma: f32,
    pub cos_weight: f32,
    pub seed: u64,
    /// Minimizer location (per-coordinate). Zero by default; set to an
    /// off-grid value to expose the weight-quantization floor of
    /// Theorem 3.2 (a minimizer that happens to sit on the `Q_x` grid
    /// has no floor).
    pub offset: Vec<f32>,
}

impl StochasticProblem {
    pub fn new(dim: usize, sigma: f32, seed: u64) -> Self {
        Self { dim, sigma, cos_weight: 0.5, seed, offset: vec![0.0; dim] }
    }

    /// Minimizer at irrational-ish per-coordinate offsets (off every
    /// dyadic grid).
    pub fn with_offgrid_minimum(dim: usize, sigma: f32, seed: u64) -> Self {
        let mut p = Self::new(dim, sigma, seed);
        p.offset = (0..dim).map(|i| 0.077 + 0.0131 * (i as f32 * 1.7).sin()).collect();
        p
    }

    pub fn loss(&self, x: &[f32]) -> f32 {
        let c = self.cos_weight;
        x.iter()
            .zip(&self.offset)
            .map(|(&xi, &oi)| {
                let z = xi - oi;
                z * z / (1.0 + z * z) + c * (1.0 - z.cos())
            })
            .sum::<f32>()
            / self.dim as f32
    }

    /// Exact gradient.
    pub fn grad_into(&self, x: &[f32], out: &mut [f32]) {
        let c = self.cos_weight;
        let inv_d = 1.0 / self.dim as f32;
        for ((o, &xi), &oi) in out.iter_mut().zip(x).zip(&self.offset) {
            let z = xi - oi;
            let den = 1.0 + z * z;
            *o = (2.0 * z / (den * den) + c * z.sin()) * inv_d;
        }
    }

    pub fn grad_norm_sq(&self, x: &[f32]) -> f32 {
        let mut g = vec![0.0; self.dim];
        self.grad_into(x, &mut g);
        g.iter().map(|v| v * v).sum()
    }

    /// Unbiased stochastic gradient: exact gradient + bounded uniform
    /// noise, deterministic in (t, worker).
    pub fn stoch_grad_into(&self, x: &[f32], t: u64, worker: u64, out: &mut [f32]) {
        self.grad_into(x, out);
        let mut rng = crate::quant::seeded_rng(self.seed, (t << 16) ^ worker);
        let inv_d = 1.0 / self.dim as f32;
        for o in out.iter_mut() {
            *o += self.sigma * (rng.gen_f32() * 2.0 - 1.0) * inv_d;
        }
    }

    /// Deterministic non-zero starting point.
    pub fn x0(&self) -> Vec<f32> {
        (0..self.dim).map(|i| 1.5 + (i as f32 * 0.7).sin()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradient_matches_finite_difference() {
        let p = StochasticProblem::new(8, 0.0, 0);
        let x = p.x0();
        let mut g = vec![0.0; 8];
        p.grad_into(&x, &mut g);
        let h = 1e-3f32;
        for j in 0..8 {
            let mut xp = x.clone();
            let mut xm = x.clone();
            xp[j] += h;
            xm[j] -= h;
            let fd = (p.loss(&xp) - p.loss(&xm)) / (2.0 * h);
            assert!((fd - g[j]).abs() < 1e-3, "j={j}: fd={fd} g={}", g[j]);
        }
    }

    #[test]
    fn stoch_grad_is_unbiased() {
        let p = StochasticProblem::new(4, 0.5, 3);
        let x = p.x0();
        let mut exact = vec![0.0; 4];
        p.grad_into(&x, &mut exact);
        let mut acc = vec![0.0f64; 4];
        let trials = 5000u64;
        for t in 0..trials {
            let mut g = vec![0.0; 4];
            p.stoch_grad_into(&x, t, 0, &mut g);
            for (a, &gi) in acc.iter_mut().zip(&g) {
                *a += gi as f64;
            }
        }
        for (a, &e) in acc.iter().zip(&exact) {
            assert!((a / trials as f64 - e as f64).abs() < 0.01);
        }
    }

    #[test]
    fn bounded_gradient() {
        // Assumption 1: per-coordinate |phi'| <= 2*(3sqrt(3)/8)/d + c/d;
        // just scan a wide range.
        let p = StochasticProblem::new(1, 0.0, 0);
        let mut worst = 0.0f32;
        for i in -1000..1000 {
            let x = [i as f32 * 0.01];
            worst = worst.max(p.grad_norm_sq(&x).sqrt());
        }
        assert!(worst <= 2.0);
    }

    #[test]
    fn nonconvexity() {
        // second difference changes sign along an axis
        let p = StochasticProblem::new(1, 0.0, 0);
        let f = |x: f32| p.loss(&[x]);
        let h = 0.1;
        let curv = |x: f32| f(x + h) + f(x - h) - 2.0 * f(x);
        assert!(curv(0.0) > 0.0);
        assert!(curv(2.0) < 0.0);
    }
}
