//! The injected clock behind every observability timestamp.
//!
//! Timing is the one thing the round path must never do itself: the
//! INV-DET invariant bans wall-clock reads from `ps/`, `quant/` and
//! `elastic/` so fixed-seed runs stay bit-reproducible. The span layer
//! therefore reads time only through this trait, only from the
//! coordinator seam (`obs/` and `coordinator/` are outside the
//! INV-DET scope — see DESIGN.md §Observability), and only when
//! tracing is enabled:
//!
//! * [`MonoClock`] — monotonic wall clock for real runs. Lives here,
//!   not in `ps/`, precisely so it needs no lint waiver.
//! * [`TickClock`] — a deterministic counter for tests and golden
//!   fixtures: every read advances by a fixed tick, so span durations
//!   are exact, reproducible numbers.
//!
//! Timestamps are nanoseconds since an arbitrary per-clock origin
//! (process start for [`MonoClock`], zero for [`TickClock`]); only
//! differences are meaningful.

use std::time::Instant;

/// Nanosecond time source for spans and the `round_ms` CSV column.
/// `Send` so a clock can accompany a trainer onto a worker thread.
pub trait Clock: Send {
    /// Monotonic nanoseconds since this clock's origin. Takes `&mut
    /// self` so deterministic clocks can advance without interior
    /// mutability.
    fn now_ns(&mut self) -> u64;
    /// Short name for the trace header (`mono` | `tick`).
    fn name(&self) -> &'static str;
}

/// Real monotonic time ([`Instant`]-based) for live runs.
pub struct MonoClock {
    origin: Instant,
}

impl MonoClock {
    pub fn new() -> Self {
        Self { origin: Instant::now() }
    }
}

impl Default for MonoClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for MonoClock {
    fn now_ns(&mut self) -> u64 {
        // u64 nanoseconds overflow after ~584 years of process uptime.
        self.origin.elapsed().as_nanos() as u64
    }

    fn name(&self) -> &'static str {
        "mono"
    }
}

/// Deterministic test clock: every read returns the previous value
/// plus a fixed tick, starting at the tick itself. Two observed
/// instants are therefore always exactly one tick apart, which makes
/// span durations (and the `round_ms` column) exact golden numbers.
pub struct TickClock {
    now: u64,
    tick: u64,
}

impl TickClock {
    /// A clock advancing `tick_ns` nanoseconds per read.
    pub fn new(tick_ns: u64) -> Self {
        Self { now: 0, tick: tick_ns }
    }

    /// The default test clock: 1 ms per read.
    pub fn millis() -> Self {
        Self::new(1_000_000)
    }
}

impl Clock for TickClock {
    fn now_ns(&mut self) -> u64 {
        self.now += self.tick;
        self.now
    }

    fn name(&self) -> &'static str {
        "tick"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tick_clock_is_deterministic() {
        let mut c = TickClock::new(10);
        assert_eq!(c.now_ns(), 10);
        assert_eq!(c.now_ns(), 20);
        assert_eq!(c.now_ns(), 30);
        assert_eq!(c.name(), "tick");
    }

    #[test]
    fn mono_clock_is_monotonic() {
        let mut c = MonoClock::new();
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
        assert_eq!(c.name(), "mono");
    }
}
