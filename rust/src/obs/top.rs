//! Reader side of the JSONL trace: parse a (possibly still-growing)
//! trace file and render the per-shard round-time/bytes table behind
//! `qadam top`.
//!
//! The reader re-reads the whole file per refresh — traces are a few
//! KB per round at smoke scale and `qadam top` refreshes once a
//! second, so simplicity wins over an incremental tail. A partial
//! final line (the writer flushes per round, but a refresh can race a
//! flush) is skipped rather than treated as corruption.

use super::trace::{Span, SpanKind, TRACE_SCHEMA_VERSION};
use crate::util::json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

/// A parsed trace: header fields plus every span that parsed cleanly.
pub struct TraceFile {
    pub schema_version: u32,
    pub clock: String,
    pub spans: Vec<Span>,
}

impl TraceFile {
    /// Span kinds present, in lifecycle order.
    pub fn covered_kinds(&self) -> Vec<&'static str> {
        SpanKind::ALL
            .into_iter()
            .filter(|k| self.spans.iter().any(|s| s.kind == *k))
            .map(|k| k.name())
            .collect()
    }

    /// True when every lifecycle phase appears at least once — the CI
    /// smoke gate (`qadam top --check`).
    pub fn covers_lifecycle(&self) -> bool {
        self.covered_kinds().len() == SpanKind::ALL.len()
    }
}

fn parse_span(v: &json::Value) -> Result<Span> {
    let kind = v.get("span")?.as_str()?;
    let kind = SpanKind::parse(kind).with_context(|| format!("unknown span kind '{kind}'"))?;
    Ok(Span {
        round: v.get("round")?.as_i64()? as u64,
        shard: v.get("shard")?.as_i64()?,
        lane: v.get("lane")?.as_i64()?,
        kind,
        start_ns: v.get("start_ns")?.as_i64()? as u64,
        dur_ns: v.get("dur_ns")?.as_i64()? as u64,
        bytes: v.get("bytes")?.as_i64()? as u64,
    })
}

/// Read a trace file. The header line must parse and carry a schema
/// version this reader understands; span lines that fail to parse are
/// skipped (a live writer may be mid-flush).
pub fn read_trace(path: &Path) -> Result<TraceFile> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    let mut lines = text.lines();
    let header = lines.next().context("empty trace: no header line")?;
    let header = json::parse(header).context("trace header is not JSON")?;
    let schema_version = header.get("trace_schema_version")?.as_usize()? as u32;
    if schema_version != TRACE_SCHEMA_VERSION {
        bail!("trace schema v{schema_version}, this reader understands v{TRACE_SCHEMA_VERSION}");
    }
    let clock = header.get("clock")?.as_str()?.to_string();
    let spans = lines
        .filter_map(|l| json::parse(l).ok())
        .filter_map(|v| parse_span(&v).ok())
        .collect();
    Ok(TraceFile { schema_version, clock, spans })
}

#[derive(Default)]
struct ShardAgg {
    first_round: u64,
    last_round: u64,
    rounds: u64,
    /// Per-[`SpanKind`] (in `ALL` order): summed duration and span count.
    dur_ns: [u64; 4],
    spans: [u64; 4],
    down_bytes: u64,
    up_bytes: u64,
}

fn aggregate(spans: &[Span]) -> BTreeMap<i64, ShardAgg> {
    let mut by_shard: BTreeMap<i64, ShardAgg> = BTreeMap::new();
    for s in spans {
        let a = by_shard.entry(s.shard).or_default();
        if a.rounds == 0 || s.round < a.first_round {
            a.first_round = s.round;
        }
        if s.round + 1 > a.last_round {
            a.last_round = s.round + 1;
        }
        a.rounds = a.last_round - a.first_round;
        let k = SpanKind::ALL.iter().position(|k| *k == s.kind).unwrap_or(0);
        a.dur_ns[k] += s.dur_ns;
        // Only timed spans count toward the mean: byte-attribution
        // spans (dur 0) on the same shard — e.g. a serve process's
        // per-lane gather spans — must not dilute it.
        if s.dur_ns > 0 {
            a.spans[k] += 1;
        }
        match s.kind {
            SpanKind::Broadcast => a.down_bytes += s.bytes,
            SpanKind::Gather => a.up_bytes += s.bytes,
            _ => {}
        }
    }
    by_shard
}

fn mean_ms(dur_ns: u64, n: u64) -> String {
    // All-zero durations mean byte-attribution-only spans (an
    // in-process trainer can't see inside `round_sharded`): show "-",
    // not a fake 0.00.
    if n == 0 || dur_ns == 0 {
        "-".to_string()
    } else {
        format!("{:.2}", dur_ns as f64 / n as f64 / 1e6)
    }
}

/// Render the per-shard table: mean phase times (ms) and wire bytes
/// per round. Shard `-1` is the merged whole-round view.
pub fn render_table(tf: &TraceFile) -> String {
    let by_shard = aggregate(&tf.spans);
    let rounds = by_shard.values().map(|a| a.rounds).max().unwrap_or(0);
    let mut out = String::new();
    let _ = writeln!(
        out,
        "trace schema v{}  clock={}  spans={}  rounds={}",
        tf.schema_version,
        tf.clock,
        tf.spans.len(),
        rounds
    );
    let _ = writeln!(
        out,
        "{:>5} {:>7} {:>9} {:>9} {:>9} {:>10} {:>10} {:>10}",
        "shard", "rounds", "bcast_ms", "gathr_ms", "apply_ms", "requant_ms", "down_B/r", "up_B/r"
    );
    for (shard, a) in &by_shard {
        let r = a.rounds.max(1);
        let _ = writeln!(
            out,
            "{:>5} {:>7} {:>9} {:>9} {:>9} {:>10} {:>10} {:>10}",
            shard,
            a.rounds,
            mean_ms(a.dur_ns[0], a.spans[0]),
            mean_ms(a.dur_ns[1], a.spans[1]),
            mean_ms(a.dur_ns[2], a.spans[2]),
            mean_ms(a.dur_ns[3], a.spans[3]),
            a.down_bytes / r,
            a.up_bytes / r,
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::TraceWriter;

    fn write_demo(path: &Path) {
        let mut w = TraceWriter::create(path, "tick").unwrap();
        for round in 0..2u64 {
            let t0 = round * 4_000_000;
            for (i, kind) in SpanKind::ALL.into_iter().enumerate() {
                w.write_span(&Span {
                    round,
                    shard: -1,
                    lane: -1,
                    kind,
                    start_ns: t0 + i as u64 * 1_000_000,
                    dur_ns: 1_000_000,
                    bytes: if kind == SpanKind::Broadcast { 200 } else { 0 },
                })
                .unwrap();
            }
            for shard in 0..2i64 {
                w.write_span(&Span {
                    round,
                    shard,
                    lane: -1,
                    kind: SpanKind::Broadcast,
                    start_ns: t0,
                    dur_ns: 0,
                    bytes: 100,
                })
                .unwrap();
                w.write_span(&Span {
                    round,
                    shard,
                    lane: 0,
                    kind: SpanKind::Gather,
                    start_ns: t0,
                    dur_ns: 0,
                    bytes: 40,
                })
                .unwrap();
            }
        }
        w.flush().unwrap();
    }

    #[test]
    fn reads_back_what_the_writer_wrote() {
        let dir = std::env::temp_dir().join("qadam_top_test_rt");
        let p = dir.join("t.jsonl");
        write_demo(&p);
        let tf = read_trace(&p).unwrap();
        assert_eq!(tf.schema_version, TRACE_SCHEMA_VERSION);
        assert_eq!(tf.clock, "tick");
        assert_eq!(tf.spans.len(), 2 * (4 + 4));
        assert!(tf.covers_lifecycle());
        assert_eq!(tf.covered_kinds(), vec!["broadcast", "gather", "decode_apply", "requantize"]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn partial_last_line_is_skipped_not_fatal() {
        let dir = std::env::temp_dir().join("qadam_top_test_partial");
        let p = dir.join("t.jsonl");
        write_demo(&p);
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new().append(true).open(&p).unwrap();
        write!(f, "{{\"round\": 9, \"sh").unwrap(); // a refresh racing a flush
        let tf = read_trace(&p).unwrap();
        assert_eq!(tf.spans.len(), 16);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn table_aggregates_per_shard_bytes_and_merged_times() {
        let dir = std::env::temp_dir().join("qadam_top_test_table");
        let p = dir.join("t.jsonl");
        write_demo(&p);
        let tf = read_trace(&p).unwrap();
        let table = render_table(&tf);
        let merged = table.lines().find(|l| l.trim_start().starts_with("-1")).unwrap();
        // 1 ms mean per phase; 200 downlink bytes per round on the merged row.
        assert!(merged.contains("1.00"), "{table}");
        assert!(merged.contains("200"), "{table}");
        let shard0 = table.lines().find(|l| l.trim_start().starts_with("0 ")).unwrap();
        // Byte-attribution spans: dashes for times, real per-shard bytes.
        assert!(shard0.contains('-'), "{table}");
        assert!(shard0.contains("100"), "{table}");
        assert!(shard0.contains("40"), "{table}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn schema_mismatch_is_an_error() {
        let dir = std::env::temp_dir().join("qadam_top_test_schema");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.jsonl");
        std::fs::write(&p, "{\"trace_schema_version\": 99, \"clock\": \"mono\"}\n").unwrap();
        assert!(read_trace(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
