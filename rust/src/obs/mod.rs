//! Observability: round-lifecycle tracing, the metrics registry, and
//! exporters — with a zero-overhead-off guarantee.
//!
//! Layering (see DESIGN.md §Observability):
//!
//! * [`clock`] — the injected [`Clock`] trait. The *only* place the
//!   wall clock is read for telemetry; lives here (outside the
//!   INV-DET lint scope) so the seam needs no waivers.
//! * [`trace`] — [`RoundTrace`] span ring + [`TraceWriter`] JSONL
//!   output (`--trace-out`).
//! * [`registry`] — [`MetricsRegistry`]: atomic counters / gauges /
//!   fixed-bucket histograms fed from values the round already
//!   produces.
//! * [`prometheus`] — text exposition + the `--metrics-addr`
//!   `GET /metrics` listener.
//! * [`top`] — trace reader and the `qadam top` per-shard table.
//!
//! The whole subsystem hangs off one `Option<RoundObs>` in the
//! trainer (and one in `serve`). `None` — the default — means no
//! clock is read, no span recorded, no registry constructed: the
//! disabled path is a branch on a `None`, which is how tracing-off
//! runs stay bit-identical *and* allocation-identical to builds that
//! never heard of obs (`rust/tests/obs.rs`,
//! `rust/tests/alloc_regression.rs`). When enabled, every update is a
//! store into preallocated storage, and timing happens strictly at the
//! coordinator/transport seam — never inside `ps/` / `quant/` hot
//! paths.

pub mod clock;
pub mod prometheus;
pub mod registry;
pub mod top;
pub mod trace;

pub use clock::{Clock, MonoClock, TickClock};
pub use prometheus::{render, MetricsServer, CONTENT_TYPE};
pub use registry::MetricsRegistry;
pub use top::{read_trace, render_table, TraceFile};
pub use trace::{RoundTrace, Span, SpanKind, TraceWriter, TRACE_SCHEMA_VERSION};

use anyhow::Result;
use std::path::Path;
use std::sync::Arc;

/// Exporters this build ships, for the `qadam info` capability set.
pub const EXPORTERS: [&str; 2] = ["prometheus", "jsonl_trace"];

/// Every metric series the registry exports, for `qadam info`.
pub const METRIC_NAMES: [&str; 15] = [
    "qadam_rounds_total",
    "qadam_up_bytes_total",
    "qadam_down_bytes_total",
    "qadam_resyncs_total",
    "qadam_straggler_evictions_total",
    "qadam_chaos_faults_total",
    "qadam_participation",
    "qadam_ef_residual_inf_norm",
    "qadam_policy_bits",
    "qadam_train_loss",
    "qadam_test_acc",
    "qadam_round_latency_ms",
    "qadam_frame_bytes",
    "qadam_staleness_rounds",
    "qadam_stale_rejected_total",
];

/// Spans retained in-memory: enough for the merged + per-shard +
/// per-lane spans of the last few dozen rounds at smoke scale.
const TRACE_RING_CAPACITY: usize = 1024;

/// Everything one observed run carries: the injected clock, the span
/// ring, the optional JSONL writer, and the shared registry (shared so
/// a detached [`MetricsServer`] can read it).
pub struct RoundObs {
    clock: Box<dyn Clock>,
    pub trace: RoundTrace,
    writer: Option<TraceWriter>,
    pub registry: Arc<MetricsRegistry>,
}

impl RoundObs {
    pub fn new(clock: Box<dyn Clock>, nshards: usize) -> Self {
        Self {
            clock,
            trace: RoundTrace::new(TRACE_RING_CAPACITY),
            writer: None,
            registry: Arc::new(MetricsRegistry::new(nshards)),
        }
    }

    /// Attach a JSONL trace writer (creates/truncates `path`, writes
    /// the schema header).
    pub fn with_trace_out(mut self, path: &Path) -> Result<Self> {
        self.writer = Some(TraceWriter::create(path, self.clock.name())?);
        Ok(self)
    }

    pub fn clock_name(&self) -> &'static str {
        self.clock.name()
    }

    pub fn now_ns(&mut self) -> u64 {
        self.clock.now_ns()
    }

    /// Record a span: ring store, optional JSONL line, and frame-size
    /// histogram for byte-carrying spans. No allocation.
    ///
    /// Only per-shard spans (`shard >= 0`) feed the byte histogram:
    /// they are the actual wire frames. Merged (`shard = -1`) spans
    /// carry byte *totals* for the trace and would double-count.
    pub fn record(&mut self, span: Span) {
        self.trace.record(span);
        if span.bytes > 0 && span.shard >= 0 {
            self.registry.frame_bytes.observe(span.bytes);
        }
        if let Some(w) = &mut self.writer {
            // Trace IO failures must not kill training; the writer
            // reports once per flush instead (see end_round).
            let _ = w.write_span(&span);
        }
    }

    /// End-of-round: flush the trace so a live `qadam top` sees whole
    /// lines. IO errors surface here, once, as a warning.
    pub fn end_round(&mut self) {
        if let Some(w) = &mut self.writer {
            if let Err(e) = w.flush() {
                eprintln!("[obs] trace flush failed: {e}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_feeds_ring_histogram_and_jsonl() {
        let dir = std::env::temp_dir().join("qadam_obs_mod_test");
        let p = dir.join("t.jsonl");
        let mut obs = RoundObs::new(Box::new(TickClock::millis()), 2);
        obs = obs.with_trace_out(&p).unwrap();
        assert_eq!(obs.clock_name(), "tick");
        let t0 = obs.now_ns();
        let t1 = obs.now_ns();
        obs.record(Span {
            round: 0,
            shard: -1,
            lane: -1,
            kind: SpanKind::Broadcast,
            start_ns: t0,
            dur_ns: t1 - t0,
            bytes: 128,
        });
        obs.record(Span {
            round: 0,
            shard: 0,
            lane: -1,
            kind: SpanKind::Broadcast,
            start_ns: t0,
            dur_ns: 0,
            bytes: 128,
        });
        obs.end_round();
        assert_eq!(obs.trace.len(), 2);
        // only the per-shard span feeds the byte histogram — the
        // merged total would double-count
        assert_eq!(obs.registry.frame_bytes.count(), 1);
        assert_eq!(obs.registry.frame_bytes.sum(), 128);
        let tf = read_trace(&p).unwrap();
        assert_eq!(tf.clock, "tick");
        assert_eq!(tf.spans.len(), 2);
        assert_eq!(tf.spans[0].dur_ns, 1_000_000, "tick clock: exactly one tick");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn capability_constants_match_the_exposition() {
        let reg = MetricsRegistry::new(2);
        let text = render(&reg);
        for name in METRIC_NAMES {
            assert!(text.contains(name), "{name} missing from exposition");
        }
    }
}
