//! The metrics registry: a fixed set of counters, gauges and
//! fixed-bucket histograms, updated from values the round already
//! produces ([`CommStats`], [`Participation`][crate::elastic::Participation],
//! [`FaultStats`], codec-policy bits) — never from inside `ps/` /
//! `quant/` hot paths.
//!
//! Everything is atomics over preallocated storage: updating a metric
//! is a handful of relaxed stores, recording allocates nothing (the
//! counting-allocator suite asserts this), and the Prometheus exporter
//! thread reads the same registry through an `Arc` without locks.
//! Cumulative counters are fed *snapshots* (`CommStats` is already
//! cumulative) through [`Counter::set_cumulative`], which only moves
//! forward — so exposition stays monotonic even across forced resyncs
//! and retried rounds.
//!
//! Naming scheme (see DESIGN.md §Observability): every series is
//! prefixed `qadam_`, cumulative series end in `_total`, and the
//! `shard` label uses the metrics-CSV convention — `-1` is the merged
//! fleet view, `0..N` are per-shard series (emitted only by
//! multi-shard registries, like the CSV's per-shard rows).

use crate::elastic::FaultStats;
use crate::ps::protocol::CommStats;
use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    /// Add a per-event increment.
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    /// Feed a cumulative snapshot: the counter only ever moves
    /// forward, so re-feeding an old snapshot can never make the
    /// exposition non-monotonic.
    pub fn set_cumulative(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-value gauge (f64 stored as bits).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Fixed-bucket histogram over integer observations (nanoseconds,
/// bytes). Buckets are preallocated at construction; observing is a
/// linear scan plus three atomic adds.
pub struct Histogram {
    /// Upper bounds (inclusive), ascending; an implicit `+Inf` bucket
    /// follows the last bound.
    bounds: Vec<u64>,
    /// `bounds.len() + 1` per-bucket (non-cumulative) counts.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl Histogram {
    pub fn new(bounds: &[u64]) -> Self {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        Self {
            bounds: bounds.to_vec(),
            buckets: (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, v: u64) {
        let i = self.bounds.iter().position(|&b| v <= b).unwrap_or(self.bounds.len());
        self.buckets[i].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// `(upper_bound, cumulative_count)` per bucket, the `+Inf` bucket
    /// last (bound = `u64::MAX` stands in for `+Inf`).
    pub fn cumulative(&self) -> impl Iterator<Item = (u64, u64)> + '_ {
        let mut acc = 0u64;
        self.buckets.iter().enumerate().map(move |(i, b)| {
            acc += b.load(Ordering::Relaxed);
            (self.bounds.get(i).copied().unwrap_or(u64::MAX), acc)
        })
    }
}

/// Round-latency bucket bounds, nanoseconds (1 ms … 1 s, then +Inf).
pub const ROUND_LATENCY_BOUNDS_NS: [u64; 10] = [
    1_000_000,
    2_000_000,
    5_000_000,
    10_000_000,
    20_000_000,
    50_000_000,
    100_000_000,
    200_000_000,
    500_000_000,
    1_000_000_000,
];

/// Wire-frame size bucket bounds, bytes (256 B … 4 MB, then +Inf).
pub const FRAME_BYTES_BOUNDS: [u64; 8] =
    [256, 1_024, 4_096, 16_384, 65_536, 262_144, 1_048_576, 4_194_304];

/// Admitted-delta staleness bucket bounds, rounds of age (async mode;
/// 0 = fresh). Powers of two up to 64, then +Inf — τ in practice is
/// single digits, so the low buckets carry the signal.
pub const STALENESS_BOUNDS_ROUNDS: [u64; 8] = [0, 1, 2, 4, 8, 16, 32, 64];

/// Chaos-fault kind label values, in [`FaultStats`] field order.
pub const FAULT_KINDS: [&str; 5] = ["drop", "delay", "duplicate", "corrupt", "crash"];

/// Per-shard cumulative byte/round accounting.
pub struct ShardComm {
    pub up_bytes: Counter,
    pub down_bytes: Counter,
    pub resyncs: Counter,
}

impl ShardComm {
    fn new() -> Self {
        Self { up_bytes: Counter::new(), down_bytes: Counter::new(), resyncs: Counter::new() }
    }

    fn feed(&self, s: &CommStats) {
        self.up_bytes.set_cumulative(s.up_bytes);
        self.down_bytes.set_cumulative(s.down_bytes);
        self.resyncs.set_cumulative(s.resyncs);
    }
}

/// The fixed metric set one run exports. Constructed once per run
/// (with the shard count), then updated lock-free from the round loop.
pub struct MetricsRegistry {
    /// Per-shard series (`shard` label `0..N`); empty for single-shard
    /// runs, which export only the merged view — the CSV convention.
    shards: Vec<ShardComm>,
    /// Merged (`shard = -1`) accounting.
    pub merged: ShardComm,
    pub rounds: Counter,
    pub straggler_evictions: Counter,
    /// Indexed like [`FAULT_KINDS`].
    pub chaos_faults: [Counter; 5],
    pub participation: Gauge,
    pub ef_residual_inf_norm: Gauge,
    pub policy_bits: Gauge,
    pub train_loss: Gauge,
    pub test_acc: Gauge,
    pub round_latency_ns: Histogram,
    pub frame_bytes: Histogram,
    /// Age (rounds) of every delta an async round admitted; empty in
    /// sync mode, where every delta is fresh by construction.
    pub staleness_rounds: Histogram,
    /// Cumulative deltas rejected as beyond the staleness bound τ (and
    /// refunded into their senders' EF residuals).
    pub stale_rejected: Counter,
}

impl MetricsRegistry {
    pub fn new(nshards: usize) -> Self {
        Self {
            shards: if nshards > 1 {
                (0..nshards).map(|_| ShardComm::new()).collect()
            } else {
                Vec::new()
            },
            merged: ShardComm::new(),
            rounds: Counter::new(),
            straggler_evictions: Counter::new(),
            chaos_faults: std::array::from_fn(|_| Counter::new()),
            participation: Gauge::new(),
            ef_residual_inf_norm: Gauge::new(),
            policy_bits: Gauge::new(),
            train_loss: Gauge::new(),
            test_acc: Gauge::new(),
            round_latency_ns: Histogram::new(&ROUND_LATENCY_BOUNDS_NS),
            frame_bytes: Histogram::new(&FRAME_BYTES_BOUNDS),
            staleness_rounds: Histogram::new(&STALENESS_BOUNDS_ROUNDS),
            stale_rejected: Counter::new(),
        }
    }

    pub fn nshards(&self) -> usize {
        self.shards.len()
    }

    pub fn shard(&self, i: usize) -> &ShardComm {
        &self.shards[i]
    }

    /// Feed the cumulative comm snapshots: the merged view plus (in
    /// multi-shard runs) each shard's own [`CommStats`].
    pub fn observe_comm(&self, merged: &CommStats, per_shard: &[&CommStats]) {
        self.merged.feed(merged);
        self.rounds.set_cumulative(merged.rounds);
        for (reg, s) in self.shards.iter().zip(per_shard) {
            reg.feed(s);
        }
    }

    /// Feed one shard's cumulative [`CommStats`] without building a
    /// slice (the round loop's zero-alloc path). No-op for shard
    /// indices a single-shard registry doesn't carry.
    pub fn observe_shard(&self, i: usize, s: &CommStats) {
        if let Some(reg) = self.shards.get(i) {
            reg.feed(s);
        }
    }

    /// Feed a round's scalar outcomes.
    pub fn observe_round(
        &self,
        round_ns: u64,
        participation: usize,
        residual_inf_norm: f32,
        policy_bits: f64,
        train_loss: f32,
    ) {
        if round_ns > 0 {
            self.round_latency_ns.observe(round_ns);
        }
        self.participation.set(participation as f64);
        self.ef_residual_inf_norm.set(residual_inf_norm as f64);
        self.policy_bits.set(policy_bits);
        if train_loss.is_finite() {
            self.train_loss.set(train_loss as f64);
        }
    }

    /// Feed the chaos injector's cumulative fault counters.
    pub fn observe_faults(&self, f: &FaultStats) {
        for (c, v) in self
            .chaos_faults
            .iter()
            .zip([f.dropped, f.delayed, f.duplicated, f.corrupted, f.crashed])
        {
            c.set_cumulative(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_cumulative_feed_is_monotonic() {
        let c = Counter::new();
        c.set_cumulative(10);
        c.set_cumulative(7); // stale snapshot: ignored
        assert_eq!(c.get(), 10);
        c.set_cumulative(12);
        assert_eq!(c.get(), 12);
        c.add(3);
        assert_eq!(c.get(), 15);
    }

    #[test]
    fn gauge_roundtrips_f64() {
        let g = Gauge::new();
        assert_eq!(g.get(), 0.0);
        g.set(2.75);
        assert_eq!(g.get(), 2.75);
        g.set(-1.5);
        assert_eq!(g.get(), -1.5);
    }

    #[test]
    fn histogram_buckets_and_cumulative() {
        let h = Histogram::new(&[10, 100]);
        h.observe(5);
        h.observe(10); // bounds are inclusive
        h.observe(50);
        h.observe(1000); // +Inf bucket
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum(), 1065);
        let buckets: Vec<(u64, u64)> = h.cumulative().collect();
        assert_eq!(buckets, vec![(10, 2), (100, 3), (u64::MAX, 4)]);
    }

    #[test]
    fn registry_shard_series_follow_the_csv_convention() {
        assert_eq!(MetricsRegistry::new(1).nshards(), 0, "single-shard: merged view only");
        let reg = MetricsRegistry::new(2);
        assert_eq!(reg.nshards(), 2);
        let a = CommStats { down_bytes: 100, up_bytes: 40, rounds: 2, resyncs: 1 };
        let b = CommStats { down_bytes: 60, up_bytes: 20, rounds: 2, resyncs: 1 };
        let merged = CommStats { down_bytes: 160, up_bytes: 60, rounds: 2, resyncs: 2 };
        reg.observe_comm(&merged, &[&a, &b]);
        assert_eq!(reg.merged.down_bytes.get(), 160);
        assert_eq!(reg.rounds.get(), 2);
        assert_eq!(reg.shard(0).down_bytes.get(), 100);
        assert_eq!(reg.shard(1).up_bytes.get(), 20);
    }

    #[test]
    fn staleness_series_bucket_fresh_and_aged_deltas() {
        let reg = MetricsRegistry::new(1);
        reg.staleness_rounds.observe(0);
        reg.staleness_rounds.observe(1);
        reg.staleness_rounds.observe(3);
        assert_eq!(reg.staleness_rounds.count(), 3);
        let c: Vec<(u64, u64)> = reg.staleness_rounds.cumulative().collect();
        assert_eq!(c[0], (0, 1), "age-0 deltas land in the first bucket");
        assert_eq!(c[1], (1, 2));
        assert_eq!(c[3], (4, 3), "age 3 rolls into the <=4 bucket");
        reg.stale_rejected.set_cumulative(5);
        assert_eq!(reg.stale_rejected.get(), 5);
    }

    #[test]
    fn fault_feed_maps_kinds_in_order() {
        let reg = MetricsRegistry::new(1);
        let f = FaultStats { dropped: 1, delayed: 2, duplicated: 3, corrupted: 4, crashed: 5 };
        reg.observe_faults(&f);
        for (i, want) in [1u64, 2, 3, 4, 5].into_iter().enumerate() {
            assert_eq!(reg.chaos_faults[i].get(), want, "{}", FAULT_KINDS[i]);
        }
    }
}
