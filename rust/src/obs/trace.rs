//! The span layer: round-lifecycle spans in a preallocated ring
//! buffer, with an optional JSONL writer a live `qadam top` can tail.
//!
//! A *span* is one timed (or byte-attributed) slice of a round:
//!
//! * `broadcast` — encoding the downlink frames (resync or delta).
//! * `gather` — the transport round: frames out, worker compute,
//!   replies in. Over TCP this is dominated by the slowest lane.
//! * `decode_apply` — the server's fused decode→sum→apply traversal.
//! * `requantize` — re-quantizing the master at `k_x` for an eval /
//!   serving view (`output_weights`).
//!
//! The merged row of a round (`shard = -1`, `lane = -1`) carries the
//! real phase durations, measured at the coordinator seam. Per-shard
//! and per-lane spans (`shard = s`, `lane = worker`) carry *byte
//! attribution* with `dur_ns = 0` when the process cannot see inside
//! the phase (an in-process trainer drives all lanes through one
//! `round_sharded` call); a `serve` process owns exactly one shard, so
//! its spans are per-shard timings by construction. See DESIGN.md
//! §Observability for why per-lane clocks never live inside `ps/`.
//!
//! The ring buffer is preallocated at construction: recording a span
//! is a copy into a fixed slot, never an allocation — asserted by the
//! counting-allocator suite (`rust/tests/alloc_regression.rs`).

use anyhow::{Context, Result};
use std::io::{BufWriter, Write};
use std::path::Path;

/// Version stamp of the JSONL trace format; bumped when span fields or
/// semantics change. Consumers (`qadam top`, CI assertions) check it
/// from the header line.
pub const TRACE_SCHEMA_VERSION: u32 = 1;

/// What a span measures. `ALL` is the full round lifecycle, in order —
/// the CI smoke asserts a traced run covers every kind.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SpanKind {
    #[default]
    Broadcast,
    Gather,
    DecodeApply,
    Requantize,
}

impl SpanKind {
    pub const ALL: [SpanKind; 4] =
        [SpanKind::Broadcast, SpanKind::Gather, SpanKind::DecodeApply, SpanKind::Requantize];

    /// The wire name (JSONL `span` field, Prometheus label value).
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Broadcast => "broadcast",
            SpanKind::Gather => "gather",
            SpanKind::DecodeApply => "decode_apply",
            SpanKind::Requantize => "requantize",
        }
    }

    /// Inverse of [`SpanKind::name`] (trace readers).
    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.into_iter().find(|k| k.name() == s)
    }
}

/// One recorded slice of a round. `Copy` so ring-buffer writes are
/// plain stores.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Span {
    pub round: u64,
    /// Parameter-server shard, `-1` = the merged (whole-round) view —
    /// the same convention as the metrics CSV `shard` column.
    pub shard: i64,
    /// Worker lane, `-1` = not lane-specific.
    pub lane: i64,
    pub kind: SpanKind,
    /// Clock timestamp at span start (ns since the clock origin).
    pub start_ns: u64,
    /// Span duration; `0` on pure byte-attribution spans.
    pub dur_ns: u64,
    /// Wire bytes this span accounts for (frame/reply sizes), `0` for
    /// phases with no wire traffic of their own.
    pub bytes: u64,
}

/// Fixed-capacity ring of the most recent spans. Preallocated once;
/// recording overwrites the oldest entry when full.
pub struct RoundTrace {
    buf: Vec<Span>,
    /// Next write slot.
    head: usize,
    len: usize,
}

impl RoundTrace {
    pub fn new(capacity: usize) -> Self {
        Self { buf: vec![Span::default(); capacity.max(1)], head: 0, len: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Record a span: a store into the preallocated ring, never an
    /// allocation.
    pub fn record(&mut self, span: Span) {
        self.buf[self.head] = span;
        self.head = (self.head + 1) % self.buf.len();
        self.len = (self.len + 1).min(self.buf.len());
    }

    /// The retained spans, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = &Span> {
        let cap = self.buf.len();
        let start = (self.head + cap - self.len) % cap;
        (0..self.len).map(move |i| &self.buf[(start + i) % cap])
    }
}

/// Append-only JSONL trace file: one header line (schema version +
/// clock name), then one JSON object per span. Flushed per round so a
/// live `qadam top` (or `tail -f`) sees complete lines.
pub struct TraceWriter {
    out: BufWriter<std::fs::File>,
}

impl TraceWriter {
    /// Create `path` (truncating) and write the header line.
    pub fn create(path: &Path, clock_name: &str) -> Result<Self> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating trace {}", path.display()))?;
        let mut w = Self { out: BufWriter::new(f) };
        writeln!(
            w.out,
            "{{\"trace_schema_version\": {TRACE_SCHEMA_VERSION}, \"clock\": \"{clock_name}\"}}"
        )?;
        Ok(w)
    }

    pub fn write_span(&mut self, s: &Span) -> Result<()> {
        writeln!(
            self.out,
            "{{\"round\": {}, \"shard\": {}, \"lane\": {}, \"span\": \"{}\", \
             \"start_ns\": {}, \"dur_ns\": {}, \"bytes\": {}}}",
            s.round,
            s.shard,
            s.lane,
            s.kind.name(),
            s.start_ns,
            s.dur_ns,
            s.bytes
        )?;
        Ok(())
    }

    /// Flush buffered lines to disk (end of round).
    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(round: u64, kind: SpanKind) -> Span {
        Span { round, kind, ..Span::default() }
    }

    #[test]
    fn ring_keeps_the_most_recent_spans() {
        let mut tr = RoundTrace::new(3);
        assert!(tr.is_empty());
        for t in 1..=5 {
            tr.record(span(t, SpanKind::Gather));
        }
        assert_eq!(tr.len(), 3);
        assert_eq!(tr.capacity(), 3);
        let rounds: Vec<u64> = tr.iter().map(|s| s.round).collect();
        assert_eq!(rounds, vec![3, 4, 5], "oldest first, overwritten from the front");
    }

    #[test]
    fn ring_partial_fill_iterates_in_order() {
        let mut tr = RoundTrace::new(8);
        tr.record(span(1, SpanKind::Broadcast));
        tr.record(span(1, SpanKind::Gather));
        let kinds: Vec<&str> = tr.iter().map(|s| s.kind.name()).collect();
        assert_eq!(kinds, vec!["broadcast", "gather"]);
    }

    #[test]
    fn span_kind_names_roundtrip() {
        for k in SpanKind::ALL {
            assert_eq!(SpanKind::parse(k.name()), Some(k));
        }
        assert_eq!(SpanKind::parse("nonsense"), None);
    }

    #[test]
    fn jsonl_lines_parse_with_the_repo_json_reader() {
        let dir = std::env::temp_dir().join("qadam_trace_test");
        let p = dir.join("t.jsonl");
        let mut w = TraceWriter::create(&p, "tick").unwrap();
        w.write_span(&Span {
            round: 3,
            shard: -1,
            lane: -1,
            kind: SpanKind::DecodeApply,
            start_ns: 1000,
            dur_ns: 250,
            bytes: 64,
        })
        .unwrap();
        w.flush().unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let mut lines = text.lines();
        let header = crate::util::json::parse(lines.next().unwrap()).unwrap();
        assert_eq!(
            header.get("trace_schema_version").unwrap().as_usize().unwrap(),
            TRACE_SCHEMA_VERSION as usize
        );
        assert_eq!(header.get("clock").unwrap().as_str().unwrap(), "tick");
        let s = crate::util::json::parse(lines.next().unwrap()).unwrap();
        assert_eq!(s.get("span").unwrap().as_str().unwrap(), "decode_apply");
        assert_eq!(s.get("round").unwrap().as_usize().unwrap(), 3);
        assert_eq!(s.get("dur_ns").unwrap().as_usize().unwrap(), 250);
        std::fs::remove_dir_all(&dir).ok();
    }
}
