//! Prometheus text-format exposition for the [`MetricsRegistry`], plus
//! a tiny embedded HTTP listener (`GET /metrics`).
//!
//! The exporter is deliberately not mounted on the parameter server's
//! worker listener: `TcpServer::membership` treats *any* pending
//! connection as a rejoining worker, so an HTTP scrape on that port
//! would be admitted into the round. The metrics endpoint therefore
//! binds its own address (`--metrics-addr`) and serves from a detached
//! thread that only ever *reads* the shared registry atomics — it can
//! never perturb the round path, which is half of the zero-overhead
//! story (the other half: with obs off, the registry never exists).

use super::registry::{MetricsRegistry, FAULT_KINDS};
use anyhow::{Context, Result};
use std::fmt::Write as _;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;

/// The exposition content type (Prometheus text format 0.0.4).
pub const CONTENT_TYPE: &str = "text/plain; version=0.0.4";

fn f64_str(v: f64) -> String {
    // `{}` prints 2.0 as "2" and 2.75 as "2.75" — both valid exposition.
    format!("{v}")
}

fn counter(out: &mut String, name: &str, help: &str, v: u64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    let _ = writeln!(out, "{name} {v}");
}

fn sharded_counter(
    out: &mut String,
    name: &str,
    help: &str,
    reg: &MetricsRegistry,
    get: impl Fn(&super::registry::ShardComm) -> u64,
) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    let _ = writeln!(out, "{name}{{shard=\"-1\"}} {}", get(&reg.merged));
    for i in 0..reg.nshards() {
        let _ = writeln!(out, "{name}{{shard=\"{i}\"}} {}", get(reg.shard(i)));
    }
}

fn gauge(out: &mut String, name: &str, help: &str, v: f64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
    let _ = writeln!(out, "{name} {}", f64_str(v));
}

/// Render a histogram whose raw `u64` observations are scaled by
/// `1/scale` on the way out (`scale = 1e6` turns stored nanoseconds
/// into exported milliseconds; `scale = 1.0` exports raw).
fn histogram(out: &mut String, name: &str, help: &str, h: &super::registry::Histogram, scale: f64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    for (bound, cum) in h.cumulative() {
        if bound == u64::MAX {
            let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
        } else {
            let le = f64_str(bound as f64 / scale);
            let _ = writeln!(out, "{name}_bucket{{le=\"{le}\"}} {cum}");
        }
    }
    let _ = writeln!(out, "{name}_sum {}", f64_str(h.sum() as f64 / scale));
    let _ = writeln!(out, "{name}_count {}", h.count());
}

/// Render the full registry in Prometheus text format 0.0.4.
pub fn render(reg: &MetricsRegistry) -> String {
    let mut out = String::with_capacity(4096);
    counter(&mut out, "qadam_rounds_total", "Training rounds completed.", reg.rounds.get());
    sharded_counter(
        &mut out,
        "qadam_up_bytes_total",
        "Uplink wire bytes (workers to server).",
        reg,
        |s| s.up_bytes.get(),
    );
    sharded_counter(
        &mut out,
        "qadam_down_bytes_total",
        "Downlink wire bytes (server to workers).",
        reg,
        |s| s.down_bytes.get(),
    );
    sharded_counter(
        &mut out,
        "qadam_resyncs_total",
        "Full-precision resync broadcasts.",
        reg,
        |s| s.resyncs.get(),
    );
    counter(
        &mut out,
        "qadam_straggler_evictions_total",
        "Worker lanes evicted by the straggler deadline.",
        reg.straggler_evictions.get(),
    );
    let _ =
        writeln!(out, "# HELP qadam_chaos_faults_total Faults injected by the chaos plan, by kind.");
    let _ = writeln!(out, "# TYPE qadam_chaos_faults_total counter");
    for (i, kind) in FAULT_KINDS.iter().enumerate() {
        let v = reg.chaos_faults[i].get();
        let _ = writeln!(out, "qadam_chaos_faults_total{{kind=\"{kind}\"}} {v}");
    }
    gauge(
        &mut out,
        "qadam_participation",
        "Workers present in the last round.",
        reg.participation.get(),
    );
    gauge(
        &mut out,
        "qadam_ef_residual_inf_norm",
        "Infinity norm of the error-feedback residual (worker 0).",
        reg.ef_residual_inf_norm.get(),
    );
    gauge(
        &mut out,
        "qadam_policy_bits",
        "Mean per-tensor codec-policy bits chosen in the last round.",
        reg.policy_bits.get(),
    );
    gauge(&mut out, "qadam_train_loss", "Last observed training loss.", reg.train_loss.get());
    gauge(&mut out, "qadam_test_acc", "Last observed test accuracy.", reg.test_acc.get());
    histogram(
        &mut out,
        "qadam_round_latency_ms",
        "End-to-end round latency, milliseconds.",
        &reg.round_latency_ns,
        1e6,
    );
    histogram(&mut out, "qadam_frame_bytes", "Wire frame sizes, bytes.", &reg.frame_bytes, 1.0);
    histogram(
        &mut out,
        "qadam_staleness_rounds",
        "Age in rounds of admitted deltas (async mode).",
        &reg.staleness_rounds,
        1.0,
    );
    counter(
        &mut out,
        "qadam_stale_rejected_total",
        "Deltas rejected as beyond the staleness bound and refunded into EF residuals.",
        reg.stale_rejected.get(),
    );
    out
}

/// A detached `/metrics` listener. Holds no join handle on purpose:
/// the thread only reads atomics and dies with the process.
pub struct MetricsServer {
    addr: SocketAddr,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9184`, port `0` for ephemeral) and
    /// serve `GET /metrics` from a background thread.
    pub fn spawn(addr: &str, registry: Arc<MetricsRegistry>) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding metrics addr {addr}"))?;
        let local = listener.local_addr()?;
        std::thread::spawn(move || {
            for s in listener.incoming().flatten() {
                let _ = handle(s, &registry);
            }
        });
        Ok(Self { addr: local })
    }

    /// The bound address (resolves port `0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

fn handle(mut stream: TcpStream, registry: &MetricsRegistry) -> Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_secs(2)))?;
    // Read the request line; scrapes are tiny, one read suffices for
    // well-formed clients and anything else gets a 400/404.
    let mut buf = [0u8; 1024];
    let n = stream.read(&mut buf)?;
    let req = String::from_utf8_lossy(&buf[..n]);
    let parts: Vec<&str> =
        req.lines().next().map(|l| l.split_whitespace().collect()).unwrap_or_default();
    let (status, ctype, body) = match parts.as_slice() {
        ["GET", "/metrics", ..] => ("200 OK", CONTENT_TYPE, render(registry)),
        ["GET", ..] if parts.len() >= 2 => {
            ("404 Not Found", "text/plain", "only /metrics lives here\n".to_string())
        }
        _ => ("400 Bad Request", "text/plain", "bad request\n".to_string()),
    };
    write!(
        stream,
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::protocol::CommStats;

    /// Golden exposition fixture: a registry with known values renders
    /// byte-exactly. Guards series names, label scheme, and bucket
    /// scaling against silent drift (dashboards parse this text).
    #[test]
    fn golden_exposition_two_shards() {
        let reg = MetricsRegistry::new(2);
        let a = CommStats { down_bytes: 100, up_bytes: 40, rounds: 3, resyncs: 1 };
        let b = CommStats { down_bytes: 60, up_bytes: 20, rounds: 3, resyncs: 1 };
        let merged = CommStats { down_bytes: 160, up_bytes: 60, rounds: 3, resyncs: 2 };
        reg.observe_comm(&merged, &[&a, &b]);
        reg.observe_round(2_000_000, 4, 0.5, 2.75, 0.125);
        reg.test_acc.set(0.75);
        reg.frame_bytes.observe(100);
        reg.staleness_rounds.observe(1);
        reg.stale_rejected.set_cumulative(2);
        let text = render(&reg);
        for want in [
            "# TYPE qadam_rounds_total counter\nqadam_rounds_total 3\n",
            "qadam_up_bytes_total{shard=\"-1\"} 60\n",
            "qadam_up_bytes_total{shard=\"0\"} 40\n",
            "qadam_up_bytes_total{shard=\"1\"} 20\n",
            "qadam_down_bytes_total{shard=\"-1\"} 160\n",
            "qadam_resyncs_total{shard=\"-1\"} 2\nqadam_resyncs_total{shard=\"0\"} 1\n",
            "qadam_straggler_evictions_total 0\n",
            "qadam_chaos_faults_total{kind=\"drop\"} 0\n",
            "qadam_chaos_faults_total{kind=\"crash\"} 0\n",
            "# TYPE qadam_participation gauge\nqadam_participation 4\n",
            "qadam_ef_residual_inf_norm 0.5\n",
            "qadam_policy_bits 2.75\n",
            "qadam_train_loss 0.125\n",
            "qadam_test_acc 0.75\n",
            // 2ms observation: le="1" misses it, le="2" catches it.
            "qadam_round_latency_ms_bucket{le=\"1\"} 0\n",
            "qadam_round_latency_ms_bucket{le=\"2\"} 1\n",
            "qadam_round_latency_ms_bucket{le=\"+Inf\"} 1\n",
            "qadam_round_latency_ms_sum 2\nqadam_round_latency_ms_count 1\n",
            "qadam_frame_bytes_bucket{le=\"256\"} 1\n",
            "qadam_frame_bytes_sum 100\nqadam_frame_bytes_count 1\n",
            // an age-1 observation: le="0" misses it, le="1" catches it
            "qadam_staleness_rounds_bucket{le=\"0\"} 0\n",
            "qadam_staleness_rounds_bucket{le=\"1\"} 1\n",
            "qadam_staleness_rounds_count 1\n",
            "qadam_stale_rejected_total 2\n",
        ] {
            assert!(text.contains(want), "missing exposition fragment:\n{want}\nin:\n{text}");
        }
    }

    #[test]
    fn single_shard_renders_only_the_merged_series() {
        let reg = MetricsRegistry::new(1);
        reg.observe_comm(&CommStats { down_bytes: 8, up_bytes: 4, rounds: 1, resyncs: 1 }, &[]);
        let text = render(&reg);
        assert!(text.contains("qadam_up_bytes_total{shard=\"-1\"} 4\n"));
        assert!(!text.contains("shard=\"0\""));
    }

    #[test]
    fn serves_metrics_over_a_real_socket() {
        let reg = Arc::new(MetricsRegistry::new(1));
        reg.rounds.set_cumulative(7);
        let srv = MetricsServer::spawn("127.0.0.1:0", reg).unwrap();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        write!(s, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"), "{resp}");
        assert!(resp.contains(&format!("Content-Type: {CONTENT_TYPE}\r\n")), "{resp}");
        assert!(resp.contains("qadam_rounds_total 7\n"), "{resp}");

        let mut s = TcpStream::connect(srv.addr()).unwrap();
        write!(s, "GET /else HTTP/1.1\r\n\r\n").unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 404"), "{resp}");
    }
}
