"""L1 Pallas kernels: the fused quantized-Adam worker step.

The per-worker hot spot of the paper (Alg. 1 lines 3-6 / Alg. 3 lines 4-7)
is a fused element-wise chain over the whole parameter vector:

    v' = theta*v + (1-theta) g^2
    m' = beta*m  + (1-beta)  g
    u  = alpha * m'/sqrt(v'+eps) + e        (error-feedback add)
    s  = ||u||_inf                           (global reduction)
    qdelta = Q_g(u; s, k_g)                  (log-level quantization)
    e' = u - qdelta                          (new error)

TPU mapping (DESIGN.md §Hardware-Adaptation): the flat chunk is reshaped
to (rows, 128) and tiled into (8, 128) VMEM blocks via BlockSpec — the
VPU-native tile.  The ∞-norm is a two-pass scheme: pass 1 fuses the
moment/update math and emits per-block partial maxima; the scalar max and
the quantization pass run next.  Everything is lowered with
``interpret=True`` (CPU PJRT cannot execute Mosaic custom-calls); the
BlockSpec structure is what carries over to a real TPU build.

All hyperparameters are runtime scalars (f32[1,1] operands in SMEM-style
blocks) so a single AOT artifact serves every (alpha_t, theta_t, beta,
eps, k_g) configuration.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# TPU-native tile: 8 sublanes x 128 lanes.
LANES = 128
SUBLANES = 8
BLOCK = (SUBLANES, LANES)
# Default flat chunk the Rust runtime feeds per pallas_call: 64Ki f32 = 256 KiB
# per tensor; 5 live tensors/block stay far under a ~16 MiB VMEM budget.
CHUNK = 65536

_scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
_block_spec = pl.BlockSpec(BLOCK, lambda i: (i, 0))


def _moments_kernel(beta_ref, theta_ref, alpha_ref, eps_ref,
                    m_ref, v_ref, g_ref, e_ref,
                    m1_ref, v1_ref, u_ref, smax_ref):
    """Pass 1: fused moment update + update direction + per-block |u| max."""
    beta = beta_ref[0, 0]
    theta = theta_ref[0, 0]
    alpha = alpha_ref[0, 0]
    eps = eps_ref[0, 0]
    g = g_ref[...]
    m1 = beta * m_ref[...] + (1.0 - beta) * g
    v1 = theta * v_ref[...] + (1.0 - theta) * g * g
    u = alpha * m1 * jax.lax.rsqrt(v1 + eps) + e_ref[...]
    m1_ref[...] = m1
    v1_ref[...] = v1
    u_ref[...] = u
    smax_ref[0, 0] = jnp.max(jnp.abs(u))


def _quantize_kernel(s_ref, qlo_ref, u_ref, q_ref, e1_ref):
    """Pass 2: log-level quantization of u at global scale s + new error.

    Same closed form as ``ref.ref_log_quantize`` — nearest power-of-two
    level in linear distance, ties up, zero below the 0/qlo midpoint.
    """
    s = s_ref[0, 0]
    qlo = qlo_ref[0, 0]
    u = u_ref[...]
    safe_s = jnp.where(s > 0.0, s, 1.0)
    a = jnp.minimum(jnp.abs(u) / safe_s, 1.0)
    loga = jnp.log2(jnp.maximum(a, 1e-38))
    m = jnp.clip(jnp.floor(loga), jnp.log2(qlo), 0.0)
    base = jnp.exp2(m)
    q = jnp.where(a < 1.5 * base, base, jnp.minimum(2.0 * base, 1.0))
    q = jnp.where(a < 0.5 * qlo, 0.0, q)
    qdelta = jnp.sign(u) * q * s
    q_ref[...] = qdelta
    e1_ref[...] = u - qdelta


def _wquant_kernel(kx_ref, x_ref, o_ref):
    """Server-side uniform weight quantizer Q_x (see ref.ref_wquant)."""
    kx = kx_ref[0, 0]
    y = jnp.clip(2.0 * x_ref[...], -1.0, 1.0) * kx
    r = jnp.sign(y) * jnp.floor(jnp.abs(y) + 0.5)
    o_ref[...] = 0.5 * r / kx


def _as_tiles(x: jnp.ndarray) -> jnp.ndarray:
    n = x.size
    if n % (SUBLANES * LANES) != 0:
        raise ValueError(f"flat size {n} not a multiple of {SUBLANES * LANES}")
    return x.reshape(n // LANES, LANES)


def _scal(x) -> jnp.ndarray:
    return jnp.asarray(x, jnp.float32).reshape(1, 1)


def qadam_moments(m, v, g, e, alpha, beta, theta, eps):
    """Pallas pass 1 over a flat f32 vector. Returns (m1, v1, u, s)."""
    n = m.size
    grid = (n // (SUBLANES * LANES),)
    tiles = _as_tiles(m).shape
    out_shapes = (
        jax.ShapeDtypeStruct(tiles, jnp.float32),
        jax.ShapeDtypeStruct(tiles, jnp.float32),
        jax.ShapeDtypeStruct(tiles, jnp.float32),
        jax.ShapeDtypeStruct((grid[0], 1), jnp.float32),
    )
    m1, v1, u, smax = pl.pallas_call(
        _moments_kernel,
        grid=grid,
        in_specs=[_scalar_spec] * 4 + [_block_spec] * 4,
        out_specs=(
            _block_spec, _block_spec, _block_spec,
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ),
        out_shape=out_shapes,
        interpret=True,
    )(_scal(beta), _scal(theta), _scal(alpha), _scal(eps),
      _as_tiles(m), _as_tiles(v), _as_tiles(g), _as_tiles(e))
    s = jnp.max(smax)
    return m1.reshape(n), v1.reshape(n), u.reshape(n), s


def log_quantize(u, s, qlo):
    """Pallas pass 2 over a flat f32 vector. Returns (qdelta, e1)."""
    n = u.size
    grid = (n // (SUBLANES * LANES),)
    tiles = _as_tiles(u).shape
    qdelta, e1 = pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[_scalar_spec, _scalar_spec, _block_spec],
        out_specs=(_block_spec, _block_spec),
        out_shape=(
            jax.ShapeDtypeStruct(tiles, jnp.float32),
            jax.ShapeDtypeStruct(tiles, jnp.float32),
        ),
        interpret=True,
    )(_scal(s), _scal(qlo), _as_tiles(u))
    return qdelta.reshape(n), e1.reshape(n)


def wquant(x, kx):
    """Pallas uniform weight quantizer over a flat f32 vector."""
    n = x.size
    grid = (n // (SUBLANES * LANES),)
    tiles = _as_tiles(x).shape
    out = pl.pallas_call(
        _wquant_kernel,
        grid=grid,
        in_specs=[_scalar_spec, _block_spec],
        out_specs=_block_spec,
        out_shape=jax.ShapeDtypeStruct(tiles, jnp.float32),
        interpret=True,
    )(_scal(kx), _as_tiles(x))
    return out.reshape(n)


@functools.partial(jax.jit, static_argnames=())
def qadam_step(m, v, g, e, alpha, beta, theta, eps, qlo):
    """Fused quantized-Adam worker step over a flat chunk.

    This is the function AOT-exported as ``artifacts/qadam_step.hlo.txt``
    and executed by the Rust worker on its flattened gradient.  The scale
    granularity is the chunk (per-chunk ∞-norm) — see DESIGN.md: per-chunk
    scaling preserves the Assumption-2 contraction with the same
    ``delta_g`` and is the standard practical choice.

    Returns ``(m1, v1, qdelta, e1)``.
    """
    m1, v1, u, s = qadam_moments(m, v, g, e, alpha, beta, theta, eps)
    qdelta, e1 = log_quantize(u, s, qlo)
    return m1, v1, qdelta, e1


def adam_step(m, v, g, alpha, beta, theta, eps):
    """Unquantized fused Adam step (baseline artifact): (m1, v1, delta)."""
    m1, v1, u0, _ = qadam_moments(m, v, g, jnp.zeros_like(m),
                                  alpha, beta, theta, eps)
    return m1, v1, u0
