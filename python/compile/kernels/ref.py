"""Pure-jnp oracle for the Pallas kernels.

Every function here is the *reference semantics* of a kernel in
``qadam.py`` and of the matching Rust implementation in
``rust/src/quant``.  pytest (``python/tests``) asserts the Pallas kernels
against these, and the Rust unit tests pin the same closed-form math, so
all three layers agree bit-for-bit (modulo f32 rounding of transcendental
``log2``, which both sides compute the same way).

Quantizer definitions (paper §5.1):

* Gradient quantizer ``Q_g`` — logarithmic (power-of-two) levels scaled by
  the ∞-norm::

      Q_g(g) = ||g||_inf * argmin_{ghat in G^d} || g/||g||_inf - ghat ||
      G = {-1, ..., -2^{-k_g}, 0, 2^{-k_g}, 2^{-k_g+1}, ..., 1}

  Nearest-level in linear distance; ties round *up* (toward the larger
  magnitude level).  The zero/smallest-level boundary is the midpoint
  ``2^{-(k_g+1)}``.

* Weight quantizer ``Q_x`` — uniform grid scaled by 0.5::

      Q_x(x) = 0.5 * argmin_{xhat in X} || 2x - xhat ||
      X = { i / 2^{k_x} : i = -2^{k_x}, ..., 2^{k_x} }

  i.e. clamp ``2x`` to [-1, 1], round to the nearest multiple of
  ``2^{-k_x}`` (round-half-away-from-zero, matching the Rust side), halve.
"""

from __future__ import annotations

import jax.numpy as jnp


def ref_log_quantize(u: jnp.ndarray, qlo) -> jnp.ndarray:
    """Q_g: quantize ``u`` onto ∞-norm-scaled power-of-two levels.

    ``qlo`` is the smallest positive level ``2^{-k_g}`` (a float, so a
    single artifact serves every ``k_g``).  Returns the quantized vector
    (same shape/dtype).  A zero input maps to zero output.
    """
    s = jnp.max(jnp.abs(u))
    # Avoid 0/0; if s == 0 every element is 0 and the final `where` kills it.
    safe_s = jnp.where(s > 0.0, s, 1.0)
    a = jnp.abs(u) / safe_s  # in [0, 1]
    a = jnp.minimum(a, 1.0)
    # Exponent of the level just below |y|:  m = floor(log2(a)), clamped so
    # base = 2^m lies in [qlo, 1].
    loga = jnp.log2(jnp.maximum(a, 1e-38))
    m = jnp.clip(jnp.floor(loga), jnp.log2(qlo), 0.0)
    base = jnp.exp2(m)
    # Nearest of {base, 2*base} in linear distance; tie -> upper.
    q = jnp.where(a < 1.5 * base, base, jnp.minimum(2.0 * base, 1.0))
    # Zero region: below the 0 / qlo midpoint.
    q = jnp.where(a < 0.5 * qlo, 0.0, q)
    return (jnp.sign(u) * q * s).astype(u.dtype)


def ref_wquant(x: jnp.ndarray, kx) -> jnp.ndarray:
    """Q_x: uniform weight quantizer.

    ``kx`` is passed as the number of fractional levels ``2^{k_x}``
    (e.g. kx=16.0 for k_x=4) so it can be a runtime scalar.
    Round-half-away-from-zero to match Rust's ``f32::round``.
    """
    y = jnp.clip(2.0 * x, -1.0, 1.0) * kx
    # jnp.round is round-half-to-even; emulate round-half-away-from-zero.
    r = jnp.sign(y) * jnp.floor(jnp.abs(y) + 0.5)
    return (0.5 * r / kx).astype(x.dtype)


def ref_adam_moments(m, v, g, beta, theta):
    """One step of the moment recursions (Alg. 1 lines 3-4)."""
    m1 = beta * m + (1.0 - beta) * g
    v1 = theta * v + (1.0 - theta) * g * g
    return m1, v1


def ref_qadam_step(m, v, g, e, alpha, beta, theta, eps, qlo):
    """Full fused worker step (Alg. 1 lines 3-6 / Alg. 3 lines 4-7).

    Returns ``(m1, v1, qdelta, e1)`` where ``qdelta`` is the quantized
    update to ship to the server and ``e1`` the new error-feedback state.
    The update direction uses the paper's sign convention:
    ``u = alpha * m1 / sqrt(v1 + eps) + e`` and the server applies
    ``x <- x - qdelta``.
    """
    m1, v1 = ref_adam_moments(m, v, g, beta, theta)
    u = alpha * m1 / jnp.sqrt(v1 + eps) + e
    qdelta = ref_log_quantize(u, qlo)
    e1 = u - qdelta
    return m1, v1, qdelta, e1


def ref_adam_step(m, v, g, alpha, beta, theta, eps):
    """Unquantized generic-Adam direction (baseline): returns (m1, v1, delta)."""
    m1, v1 = ref_adam_moments(m, v, g, beta, theta)
    return m1, v1, alpha * m1 / jnp.sqrt(v1 + eps)
