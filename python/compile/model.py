"""L2: JAX model definitions whose fwd/bwd graphs are AOT-exported.

Paper workloads and their CPU-scale stand-ins (DESIGN.md §Substitutions):

* ``mlp``          — 2-layer MLP on 64-d feature vectors (quickstart /
                     convergence-theory checks).
* ``vgg_sim``      — small VGG-style conv net, 10 classes, 32x32x3
                     (stands in for VGG16/CIFAR10, Table 3 / Fig 4).
* ``resnet_sim``   — deeper residual conv net, 20 classes, 32x32x3
                     (stands in for ResNet-101/CIFAR100, Table 2 / Fig 3).
* ``transformer``  — causal char-level transformer LM (the mandated
                     end-to-end workload, examples/train_transformer.rs).
* ``transformer_small`` — 2-layer variant for tests.

Every model is a pure function over an *ordered list* of f32 parameter
arrays.  The order is the contract with the Rust side: ``aot.py`` writes
it to ``artifacts/manifest.json`` and ``rust/src/models`` flattens /
unflattens PS tensors in exactly that order.

Exported graphs per model (see aot.py):
  grad_<name>.hlo.txt : (*params, x, y) -> (loss, *grads)
  eval_<name>.hlo.txt : (*params, x)    -> logits
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: Tuple[int, ...]

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """A model with a fixed (batch-static) train/eval configuration."""

    name: str
    params: List[ParamSpec]
    apply: Callable  # (params, x) -> logits
    # Input specs (without params): train takes (x, y), eval takes (x,).
    train_x: Tuple[Tuple[int, ...], str]
    train_y: Tuple[Tuple[int, ...], str]
    eval_x: Tuple[Tuple[int, ...], str]
    num_classes: int
    kind: str  # "classifier" | "lm"

    @property
    def total_params(self) -> int:
        return sum(p.size for p in self.params)

    def init(self, seed: int = 0) -> List[jnp.ndarray]:
        """He-style init, deterministic in ``seed``."""
        rng = np.random.default_rng(seed)
        out = []
        for p in self.params:
            if p.name.endswith("_b") or "_bias" in p.name:
                out.append(jnp.zeros(p.shape, jnp.float32))
            elif "emb" in p.name:
                out.append(jnp.asarray(
                    rng.normal(0, 0.02, p.shape), jnp.float32))
            elif "_scale" in p.name:
                out.append(jnp.ones(p.shape, jnp.float32))
            else:
                fan_in = int(np.prod(p.shape[:-1])) or 1
                std = float(np.sqrt(2.0 / fan_in))
                out.append(jnp.asarray(
                    rng.normal(0, std, p.shape), jnp.float32))
        return out

    def loss(self, params, x, y):
        logits = self.apply(params, x)
        if self.kind == "lm":
            logits = logits.reshape(-1, logits.shape[-1])
            y = y.reshape(-1)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)
        return jnp.mean(nll)

    def grad_fn(self):
        def f(*args):
            params = list(args[: len(self.params)])
            x, y = args[len(self.params)], args[len(self.params) + 1]
            loss, grads = jax.value_and_grad(self.loss)(params, x, y)
            return (loss, *grads)
        return f

    def eval_fn(self):
        def f(*args):
            params = list(args[: len(self.params)])
            x = args[len(self.params)]
            return (self.apply(params, x),)
        return f


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def _make_mlp(name: str, d_in: int, hidden: Sequence[int], n_cls: int,
              batch: int, eval_batch: int) -> ModelSpec:
    dims = [d_in, *hidden, n_cls]
    specs = []
    for i in range(len(dims) - 1):
        specs.append(ParamSpec(f"fc{i}_w", (dims[i], dims[i + 1])))
        specs.append(ParamSpec(f"fc{i}_b", (dims[i + 1],)))

    n_layers = len(dims) - 1

    def apply(params, x):
        h = x
        for i in range(n_layers):
            w, b = params[2 * i], params[2 * i + 1]
            h = h @ w + b
            if i < n_layers - 1:
                h = jax.nn.relu(h)
        return h

    return ModelSpec(
        name=name, params=specs, apply=apply,
        train_x=((batch, d_in), "f32"), train_y=((batch,), "i32"),
        eval_x=((eval_batch, d_in), "f32"),
        num_classes=n_cls, kind="classifier",
    )


# ---------------------------------------------------------------------------
# Conv nets
# ---------------------------------------------------------------------------

def _conv(x, w, b, stride: int = 1):
    """NHWC conv3x3 (or wxw) + bias, SAME padding."""
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _make_vgg_sim(batch: int, eval_batch: int) -> ModelSpec:
    """Small VGG-style net: [32,32]x2 pool [64,64] pool [128] pool fc."""
    chans = [(3, 32), (32, 32), (32, 64), (64, 64), (64, 128)]
    pools_after = {1, 3, 4}  # pool after conv index
    n_cls = 10
    specs = []
    for i, (ci, co) in enumerate(chans):
        specs.append(ParamSpec(f"conv{i}_w", (3, 3, ci, co)))
        specs.append(ParamSpec(f"conv{i}_b", (co,)))
    # After 3 pools: 32 -> 16 -> 8 -> 4 spatial, 128 channels.
    specs.append(ParamSpec("fc0_w", (4 * 4 * 128, 256)))
    specs.append(ParamSpec("fc0_b", (256,)))
    specs.append(ParamSpec("fc1_w", (256, n_cls)))
    specs.append(ParamSpec("fc1_b", (n_cls,)))

    def apply(params, x):
        h = x
        idx = 0
        for i in range(len(chans)):
            h = jax.nn.relu(_conv(h, params[idx], params[idx + 1]))
            idx += 2
            if i in pools_after:
                h = jax.lax.reduce_window(
                    h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1),
                    "VALID")
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(h @ params[idx] + params[idx + 1])
        return h @ params[idx + 2] + params[idx + 3]

    return ModelSpec(
        name="vgg_sim", params=specs, apply=apply,
        train_x=((batch, 32, 32, 3), "f32"), train_y=((batch,), "i32"),
        eval_x=((eval_batch, 32, 32, 3), "f32"),
        num_classes=n_cls, kind="classifier",
    )


def _make_resnet_sim(batch: int, eval_batch: int) -> ModelSpec:
    """Residual conv net: stem + 3 stages x 2 residual blocks, 20 classes."""
    n_cls = 20
    stages = [32, 64, 128]
    specs = [ParamSpec("stem_w", (3, 3, 3, stages[0])),
             ParamSpec("stem_b", (stages[0],))]
    for s, ch in enumerate(stages):
        cin = stages[s - 1] if s > 0 else stages[0]
        # downsample conv (stride 2) when changing stage (except stage 0)
        if s > 0:
            specs.append(ParamSpec(f"s{s}_down_w", (1, 1, cin, ch)))
            specs.append(ParamSpec(f"s{s}_down_b", (ch,)))
        for b in range(2):
            specs.append(ParamSpec(f"s{s}b{b}_c0_w", (3, 3, ch, ch)))
            specs.append(ParamSpec(f"s{s}b{b}_c0_b", (ch,)))
            specs.append(ParamSpec(f"s{s}b{b}_c1_w", (3, 3, ch, ch)))
            specs.append(ParamSpec(f"s{s}b{b}_c1_b", (ch,)))
    specs.append(ParamSpec("fc_w", (stages[-1], n_cls)))
    specs.append(ParamSpec("fc_b", (n_cls,)))

    def apply(params, x):
        it = iter(range(len(params)))
        nxt = lambda: params[next(it)]
        h = jax.nn.relu(_conv(x, nxt(), nxt()))
        for s in range(len(stages)):
            if s > 0:
                h = _conv(h, nxt(), nxt(), stride=2)
            for _ in range(2):
                # Fixup-style 0.25 branch scale: the net has no
                # normalization layers, so unscaled residual sums blow the
                # logit scale up (~15 std at init) and freeze training.
                r = h
                h = jax.nn.relu(_conv(h, nxt(), nxt()))
                h = _conv(h, nxt(), nxt())
                h = jax.nn.relu(0.25 * h + r)
        h = jnp.mean(h, axis=(1, 2))  # global average pool
        return 0.25 * (h @ nxt()) + nxt()

    return ModelSpec(
        name="resnet_sim", params=specs, apply=apply,
        train_x=((batch, 32, 32, 3), "f32"), train_y=((batch,), "i32"),
        eval_x=((eval_batch, 32, 32, 3), "f32"),
        num_classes=n_cls, kind="classifier",
    )


# ---------------------------------------------------------------------------
# Transformer LM
# ---------------------------------------------------------------------------

def _make_transformer(name: str, vocab: int, d_model: int, n_head: int,
                      n_layer: int, seq: int, batch: int,
                      eval_batch: int) -> ModelSpec:
    d_ff = 4 * d_model
    specs = [ParamSpec("tok_emb", (vocab, d_model)),
             ParamSpec("pos_emb", (seq, d_model))]
    for l in range(n_layer):
        specs += [
            ParamSpec(f"l{l}_ln1_scale", (d_model,)),
            ParamSpec(f"l{l}_ln1_b", (d_model,)),
            ParamSpec(f"l{l}_attn_qkv_w", (d_model, 3 * d_model)),
            ParamSpec(f"l{l}_attn_qkv_b", (3 * d_model,)),
            ParamSpec(f"l{l}_attn_out_w", (d_model, d_model)),
            ParamSpec(f"l{l}_attn_out_b", (d_model,)),
            ParamSpec(f"l{l}_ln2_scale", (d_model,)),
            ParamSpec(f"l{l}_ln2_b", (d_model,)),
            ParamSpec(f"l{l}_mlp_in_w", (d_model, d_ff)),
            ParamSpec(f"l{l}_mlp_in_b", (d_ff,)),
            ParamSpec(f"l{l}_mlp_out_w", (d_ff, d_model)),
            ParamSpec(f"l{l}_mlp_out_b", (d_model,)),
        ]
    specs += [ParamSpec("lnf_scale", (d_model,)), ParamSpec("lnf_b", (d_model,))]
    # Weight-tied output head (reuses tok_emb) keeps the param list small
    # and matches the standard small-LM recipe.

    head_dim = d_model // n_head
    assert head_dim * n_head == d_model

    def layernorm(x, scale, b):
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
        return (x - mu) * jax.lax.rsqrt(var + 1e-5) * scale + b

    def apply(params, x):
        it = iter(range(len(params)))
        nxt = lambda: params[next(it)]
        tok_emb = nxt()
        pos_emb = nxt()
        B, T = x.shape
        h = tok_emb[x] + pos_emb[None, :T, :]
        mask = jnp.tril(jnp.ones((T, T), jnp.float32))
        neg = jnp.float32(-1e9)
        for _ in range(n_layer):
            ln1s, ln1b = nxt(), nxt()
            qkv_w, qkv_b = nxt(), nxt()
            out_w, out_b = nxt(), nxt()
            ln2s, ln2b = nxt(), nxt()
            mi_w, mi_b = nxt(), nxt()
            mo_w, mo_b = nxt(), nxt()

            a = layernorm(h, ln1s, ln1b)
            qkv = a @ qkv_w + qkv_b
            q, k, v = jnp.split(qkv, 3, axis=-1)

            def heads(t):
                return t.reshape(B, T, n_head, head_dim).transpose(0, 2, 1, 3)

            q, k, v = heads(q), heads(k), heads(v)
            att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(
                jnp.float32(head_dim))
            att = jnp.where(mask[None, None] > 0, att, neg)
            att = jax.nn.softmax(att, axis=-1)
            o = (att @ v).transpose(0, 2, 1, 3).reshape(B, T, d_model)
            h = h + o @ out_w + out_b

            a = layernorm(h, ln2s, ln2b)
            h = h + jax.nn.gelu(a @ mi_w + mi_b) @ mo_w + mo_b

        h = layernorm(h, nxt(), nxt())
        return h @ tok_emb.T  # tied head

    return ModelSpec(
        name=name, params=specs, apply=apply,
        train_x=((batch, seq), "i32"), train_y=((batch, seq), "i32"),
        eval_x=((eval_batch, seq), "i32"),
        num_classes=vocab, kind="lm",
    )


# ---------------------------------------------------------------------------
# Registry — per-worker batch 16 matches the paper's setup (8 workers x 16).
# ---------------------------------------------------------------------------

def build_registry() -> dict:
    return {
        "mlp": _make_mlp("mlp", d_in=64, hidden=[256, 256], n_cls=10,
                         batch=16, eval_batch=256),
        "vgg_sim": _make_vgg_sim(batch=16, eval_batch=256),
        "resnet_sim": _make_resnet_sim(batch=16, eval_batch=256),
        "transformer": _make_transformer(
            "transformer", vocab=256, d_model=256, n_head=8, n_layer=4,
            seq=128, batch=8, eval_batch=32),
        "transformer_small": _make_transformer(
            "transformer_small", vocab=64, d_model=64, n_head=4, n_layer=2,
            seq=32, batch=8, eval_batch=32),
    }


MODELS = build_registry()
