"""AOT exporter: lower every L2/L1 graph to HLO *text* + manifest.json.

HLO text (NOT ``lowered.compile()`` / ``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Exports (all under ``artifacts/``):

  grad_<model>.hlo.txt   (*params, x, y) -> (loss, *grads)
  eval_<model>.hlo.txt   (*params, x)    -> (logits,)
  qadam_step.hlo.txt     fused Pallas worker step over a flat CHUNK
                         (m, v, g, e, alpha, beta, theta, eps, qlo)
                         -> (m1, v1, qdelta, e1)
  adam_step.hlo.txt      unquantized baseline step -> (m1, v1, delta)
  wquant.hlo.txt         server weight quantizer (x, kx) -> (qx,)
  manifest.json          shapes / param order / chunk metadata for rust

Usage:  cd python && python -m compile.aot --out ../artifacts
        (the Makefile drives this; it is a no-op for unchanged inputs
        because make checks the timestamps.)
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as model_lib
from compile.kernels import qadam


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _shape_struct(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.int32 if dtype == "i32"
                                else jnp.float32)


def export_model(spec, outdir, manifest, selected):
    entry = {
        "params": [{"name": p.name, "shape": list(p.shape)}
                   for p in spec.params],
        "total_params": spec.total_params,
        "train_x": {"shape": list(spec.train_x[0]), "dtype": spec.train_x[1]},
        "train_y": {"shape": list(spec.train_y[0]), "dtype": spec.train_y[1]},
        "eval_x": {"shape": list(spec.eval_x[0]), "dtype": spec.eval_x[1]},
        "num_classes": spec.num_classes,
        "kind": spec.kind,
        "grad_artifact": f"grad_{spec.name}.hlo.txt",
        "eval_artifact": f"eval_{spec.name}.hlo.txt",
    }
    manifest["models"][spec.name] = entry
    if not selected:
        return
    params_struct = [_shape_struct(p.shape, "f32") for p in spec.params]
    x = _shape_struct(*spec.train_x)
    y = _shape_struct(*spec.train_y)
    ex = _shape_struct(*spec.eval_x)

    lowered = jax.jit(spec.grad_fn()).lower(*params_struct, x, y)
    path = os.path.join(outdir, entry["grad_artifact"])
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    print(f"  {entry['grad_artifact']:32s} params={spec.total_params}")

    lowered = jax.jit(spec.eval_fn()).lower(*params_struct, ex)
    with open(os.path.join(outdir, entry["eval_artifact"]), "w") as f:
        f.write(to_hlo_text(lowered))


def export_optimizer(outdir, manifest):
    chunk = qadam.CHUNK
    vec = jax.ShapeDtypeStruct((chunk,), jnp.float32)
    scal = jax.ShapeDtypeStruct((), jnp.float32)

    lowered = jax.jit(qadam.qadam_step).lower(
        vec, vec, vec, vec, scal, scal, scal, scal, scal)
    with open(os.path.join(outdir, "qadam_step.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    lowered = jax.jit(qadam.adam_step).lower(
        vec, vec, vec, scal, scal, scal, scal)
    with open(os.path.join(outdir, "adam_step.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    lowered = jax.jit(qadam.wquant).lower(vec, scal)
    with open(os.path.join(outdir, "wquant.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    manifest["optimizer"] = {
        "chunk": chunk,
        "qadam_artifact": "qadam_step.hlo.txt",
        "qadam_scalars": ["alpha", "beta", "theta", "eps", "qlo"],
        "adam_artifact": "adam_step.hlo.txt",
        "adam_scalars": ["alpha", "beta", "theta", "eps"],
        "wquant_artifact": "wquant.hlo.txt",
        "wquant_scalars": ["kx"],
    }
    print(f"  qadam_step/adam_step/wquant     chunk={chunk}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="all",
                    help="comma list of models to lower, or 'all'/'none'. "
                         "Manifest always covers all models.")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    wanted = (set(model_lib.MODELS) if args.models == "all"
              else set() if args.models == "none"
              else set(args.models.split(",")))
    unknown = wanted - set(model_lib.MODELS)
    if unknown:
        raise SystemExit(f"unknown models: {sorted(unknown)}")

    manifest = {"models": {}, "optimizer": {}}
    print("AOT export:")
    for name, spec in model_lib.MODELS.items():
        export_model(spec, args.out, manifest, name in wanted)
    export_optimizer(args.out, manifest)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  manifest.json ({len(manifest['models'])} models)")


if __name__ == "__main__":
    main()
