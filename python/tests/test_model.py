"""L2 model graphs: shapes, gradients, loss decrease sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_lib
from compile.kernels import ref


@pytest.fixture(scope="module")
def models():
    return model_lib.MODELS


SMALL = ["mlp", "vgg_sim", "resnet_sim", "transformer_small"]


def _example_batch(spec, seed=0):
    r = np.random.default_rng(seed)
    xs, xd = spec.train_x
    ys, _ = spec.train_y
    if xd == "i32":
        x = jnp.asarray(r.integers(0, spec.num_classes, xs), jnp.int32)
    else:
        x = jnp.asarray(r.standard_normal(xs), jnp.float32)
    y = jnp.asarray(r.integers(0, spec.num_classes, ys), jnp.int32)
    return x, y


@pytest.mark.parametrize("name", SMALL)
def test_grad_fn_shapes(models, name):
    spec = models[name]
    params = spec.init(0)
    assert [tuple(p.shape) for p in params] == [p.shape for p in spec.params]
    x, y = _example_batch(spec)
    out = spec.grad_fn()(*params, x, y)
    loss, grads = out[0], out[1:]
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    assert len(grads) == len(params)
    for g, p in zip(grads, params):
        assert g.shape == p.shape
        assert np.all(np.isfinite(np.asarray(g)))


@pytest.mark.parametrize("name", SMALL)
def test_eval_fn_logits(models, name):
    spec = models[name]
    params = spec.init(0)
    r = np.random.default_rng(1)
    xs, xd = spec.eval_x
    if xd == "i32":
        x = jnp.asarray(r.integers(0, spec.num_classes, xs), jnp.int32)
    else:
        x = jnp.asarray(r.standard_normal(xs), jnp.float32)
    (logits,) = spec.eval_fn()(*params, x)
    if spec.kind == "lm":
        assert logits.shape == (*xs, spec.num_classes)
    else:
        assert logits.shape == (xs[0], spec.num_classes)
    assert np.all(np.isfinite(np.asarray(logits)))


@pytest.mark.parametrize("name", ["mlp", "transformer_small"])
def test_loss_decreases_under_qadam(models, name):
    """A few full QAdam-EF steps (via the jnp reference) reduce the loss —
    the end-to-end L1+L2 composition sanity check."""
    spec = models[name]
    params = spec.init(0)
    x, y = _example_batch(spec)
    grad = jax.jit(spec.grad_fn())

    flat = jnp.concatenate([p.reshape(-1) for p in params])
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    e = jnp.zeros_like(flat)

    def unflatten(f):
        out, off = [], 0
        for p in spec.params:
            out.append(f[off:off + p.size].reshape(p.shape))
            off += p.size
        return out

    losses = []
    for t in range(1, 16):
        outs = grad(*unflatten(flat), x, y)
        losses.append(float(outs[0]))
        gflat = jnp.concatenate([g.reshape(-1) for g in outs[1:]])
        m, v, qd, e = ref.ref_qadam_step(
            m, v, gflat, e, jnp.float32(1e-2), jnp.float32(0.9),
            jnp.float32(1 - 0.1 / t), jnp.float32(1e-5), jnp.float32(0.25))
        flat = flat - qd
    assert losses[-1] < losses[0], losses


def test_total_params_counts(models):
    # Pin the rough scale so the manifest/rust side can rely on it.
    assert models["mlp"].total_params == 64 * 256 + 256 + 256 * 256 + 256 + 256 * 10 + 10
    assert models["transformer"].total_params > 3_000_000
    assert models["resnet_sim"].total_params > 500_000
