"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes and value regimes; every assertion is
``assert_allclose`` against ``compile.kernels.ref``.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from numpy.testing import assert_allclose

from compile.kernels import qadam, ref

TILE = qadam.SUBLANES * qadam.LANES  # 1024

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25)
hypothesis.settings.load_profile("ci")


def rng_vec(seed, n, scale=1.0, loc=0.0):
    r = np.random.default_rng(seed)
    return jnp.asarray(loc + scale * r.standard_normal(n), jnp.float32)


# -- strategies -------------------------------------------------------------

sizes = st.sampled_from([TILE, 2 * TILE, 8 * TILE])
kgs = st.integers(min_value=1, max_value=8)
kxs = st.integers(min_value=1, max_value=8)
seeds = st.integers(min_value=0, max_value=2**31 - 1)
scales = st.sampled_from([1e-6, 1e-2, 1.0, 1e3])


# -- log quantizer ----------------------------------------------------------

@given(seeds, sizes, kgs, scales)
def test_log_quantize_matches_ref(seed, n, kg, scale):
    u = rng_vec(seed, n, scale)
    qlo = jnp.float32(2.0 ** -kg)
    s = jnp.max(jnp.abs(u))
    got_q, got_e = qadam.log_quantize(u, s, qlo)
    want_q = ref.ref_log_quantize(u, qlo)
    assert_allclose(np.asarray(got_q), np.asarray(want_q), rtol=1e-6,
                    atol=scale * 1e-7)
    assert_allclose(np.asarray(got_e), np.asarray(u - want_q), rtol=1e-5,
                    atol=scale * 1e-6)


def test_log_quantize_zero_vector():
    u = jnp.zeros(TILE, jnp.float32)
    q, e = qadam.log_quantize(u, jnp.float32(0.0), jnp.float32(0.25))
    assert np.all(np.asarray(q) == 0.0)
    assert np.all(np.asarray(e) == 0.0)


def test_log_quantize_levels_are_powers_of_two():
    u = rng_vec(3, 4 * TILE)
    kg = 4
    q = np.asarray(ref.ref_log_quantize(u, 2.0 ** -kg))
    s = float(np.max(np.abs(np.asarray(u))))
    lv = np.abs(q) / s
    nonzero = lv[lv > 0]
    exps = np.log2(nonzero)
    assert_allclose(exps, np.round(exps), atol=1e-5)
    assert exps.min() >= -kg - 1e-5 and exps.max() <= 1e-5


@given(seeds, kgs)
def test_log_quantize_contraction(seed, kg):
    """Assumption 2: ||u - Q_g(u)|| <= (1 - delta_g) ||u|| with delta_g > 0."""
    u = rng_vec(seed, TILE)
    q = np.asarray(ref.ref_log_quantize(u, 2.0 ** -kg))
    un = np.asarray(u)
    err = np.linalg.norm(un - q)
    assert err <= (1.0 - 2.0 ** -(kg + 2)) * np.linalg.norm(un) + 1e-6


# -- weight quantizer -------------------------------------------------------

@given(seeds, sizes, kxs, st.sampled_from([0.05, 0.3, 1.5]))
def test_wquant_matches_ref(seed, n, kx, scale):
    x = rng_vec(seed, n, scale)
    kxf = jnp.float32(2.0 ** kx)
    got = qadam.wquant(x, kxf)
    want = ref.ref_wquant(x, kxf)
    assert_allclose(np.asarray(got), np.asarray(want), atol=1e-7)


@given(seeds, kxs)
def test_wquant_bounded_error(seed, kx):
    """Assumption 3: ||x - Q_x(x)||_inf <= grid step/2 inside the grid range."""
    x = jnp.clip(rng_vec(seed, TILE, 0.2), -0.5, 0.5)  # grid range
    q = np.asarray(ref.ref_wquant(x, 2.0 ** kx))
    step = 0.5 * 2.0 ** -kx
    assert np.max(np.abs(np.asarray(x) - q)) <= step / 2 + 1e-7
    # grid membership: 2*q must be integer multiples of 2^-kx
    mult = 2.0 * q * (2.0 ** kx)
    assert_allclose(mult, np.round(mult), atol=1e-5)


def test_wquant_idempotent():
    x = rng_vec(11, TILE, 0.2)
    q1 = ref.ref_wquant(x, 16.0)
    q2 = ref.ref_wquant(q1, 16.0)
    assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-7)


# -- fused qadam step -------------------------------------------------------

@given(seeds, sizes, kgs)
def test_qadam_step_matches_ref(seed, n, kg):
    m = rng_vec(seed, n, 0.1)
    v = jnp.abs(rng_vec(seed + 1, n, 0.01))
    g = rng_vec(seed + 2, n)
    e = rng_vec(seed + 3, n, 0.001)
    hp = dict(alpha=jnp.float32(1e-3), beta=jnp.float32(0.99),
              theta=jnp.float32(0.999), eps=jnp.float32(1e-5),
              qlo=jnp.float32(2.0 ** -kg))
    got = qadam.qadam_step(m, v, g, e, **hp)
    want = ref.ref_qadam_step(m, v, g, e, **hp)
    for a, b, name in zip(got, want, ["m1", "v1", "qdelta", "e1"]):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-7,
                        err_msg=name)


def test_qadam_step_error_feedback_identity():
    """qdelta + e1 must equal the pre-quantization update u exactly."""
    n = 2 * TILE
    m, v = rng_vec(0, n, 0.1), jnp.abs(rng_vec(1, n, 0.01))
    g, e = rng_vec(2, n), rng_vec(3, n, 0.001)
    m1, v1, qd, e1 = qadam.qadam_step(
        m, v, g, e, jnp.float32(1e-3), jnp.float32(0.99),
        jnp.float32(0.999), jnp.float32(1e-5), jnp.float32(0.25))
    u = np.asarray(1e-3 * m1 / jnp.sqrt(v1 + 1e-5) + e)
    assert_allclose(np.asarray(qd) + np.asarray(e1), u, rtol=1e-6, atol=1e-8)


def test_adam_step_matches_ref():
    n = TILE
    m, v, g = rng_vec(0, n, 0.1), jnp.abs(rng_vec(1, n, 0.01)), rng_vec(2, n)
    hp = dict(alpha=jnp.float32(1e-3), beta=jnp.float32(0.99),
              theta=jnp.float32(0.999), eps=jnp.float32(1e-5))
    got = qadam.adam_step(m, v, g, **hp)
    want = ref.ref_adam_step(m, v, g, **hp)
    for a, b in zip(got, want):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-8)


def test_chunk_is_tile_aligned():
    assert qadam.CHUNK % TILE == 0
