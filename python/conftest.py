import os
import sys

# Allow running `pytest python/tests/` from the repo root: the tests
# import the `compile` package that lives next to this file.
sys.path.insert(0, os.path.dirname(__file__))
