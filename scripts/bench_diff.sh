#!/usr/bin/env bash
# Bench-trajectory gate: run the hot-path benches fresh and compare
# them against the committed baselines (BENCH_quant_micro.json,
# BENCH_worker_step.json) with `qadam bench-diff`. A fresh median more
# than THRESHOLD percent slower than its baseline fails the script.
#
#   scripts/bench_diff.sh                 # full-size run, compare both
#   scripts/bench_diff.sh --refresh       # overwrite the baselines with
#                                         # this machine's numbers
#   scripts/bench_diff.sh --quick         # CI smoke sizes (seconds);
#                                         # quick entry names differ from
#                                         # full-size ones, so against
#                                         # full baselines this mostly
#                                         # exercises the plumbing
#   scripts/bench_diff.sh --threshold 40  # loosen the gate
#
# Baselines whose medians are null (the committed placeholders) are
# reported as unmeasured and never fail — run `--refresh` (full size,
# quiet machine) once to pin real numbers, then commit the JSONs.
# `--refresh` self-checks its output with `bench-diff
# --require-measured`, which fails loudly on any remaining null median.
set -euo pipefail
cd "$(dirname "$0")/.."

REFRESH=0
QUICK=0
THRESHOLD=25
while [ $# -gt 0 ]; do
    case "$1" in
        --refresh) REFRESH=1 ;;
        --quick) QUICK=1 ;;
        --threshold) THRESHOLD="$2"; shift ;;
        *) echo "unknown flag: $1" >&2; exit 2 ;;
    esac
    shift
done

QUANT_FLAGS=()
WORKER_FLAGS=(--skip-pjrt)
if [ "$QUICK" = 1 ]; then
    QUANT_FLAGS=(--sizes 4096 --target-ms 20)
    WORKER_FLAGS=(--dim 4096 --workers 1,2 --step-dims 4096 --target-ms 20
                  --downlink-rounds 4 --skip-pjrt)
fi

FRESH_Q=/tmp/BENCH_quant_micro.fresh.json
FRESH_W=/tmp/BENCH_worker_step.fresh.json

cargo build --release --quiet
cargo bench --bench quant_micro -- "${QUANT_FLAGS[@]+"${QUANT_FLAGS[@]}"}" --json "$FRESH_Q"
cargo bench --bench worker_step -- "${WORKER_FLAGS[@]}" --json "$FRESH_W"

if [ "$REFRESH" = 1 ]; then
    if [ "$QUICK" = 1 ]; then
        echo "refusing --refresh --quick: baselines must be full-size runs" >&2
        exit 2
    fi
    cp "$FRESH_Q" BENCH_quant_micro.json
    cp "$FRESH_W" BENCH_worker_step.json
    # Self-check the refreshed baselines: compared against themselves
    # (0% diff by construction) with --require-measured, so a refresh
    # that still leaves null-median placeholders fails loudly here
    # instead of silently shrinking every future comparison.
    target/release/qadam bench-diff --baseline BENCH_quant_micro.json \
        --fresh BENCH_quant_micro.json --require-measured
    target/release/qadam bench-diff --baseline BENCH_worker_step.json \
        --fresh BENCH_worker_step.json --require-measured
    echo "baselines refreshed — commit BENCH_quant_micro.json BENCH_worker_step.json"
    exit 0
fi

target/release/qadam bench-diff --baseline BENCH_quant_micro.json \
    --fresh "$FRESH_Q" --threshold "$THRESHOLD"
target/release/qadam bench-diff --baseline BENCH_worker_step.json \
    --fresh "$FRESH_W" --threshold "$THRESHOLD"
echo "bench-diff OK"
