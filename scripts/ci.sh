#!/usr/bin/env bash
# Tier-1 + docs gate. Run from anywhere: resolves to the repo root.
#
#   scripts/ci.sh          # everything
#   scripts/ci.sh docs     # just the docs/format gate (fast)
#
# The docs gate is what keeps DESIGN.md's companion rustdoc honest:
# `cargo doc` runs with warnings promoted to errors, so broken
# intra-doc links or malformed doc comments fail CI instead of rotting.
set -euo pipefail
cd "$(dirname "$0")/.."

step() { printf '\n== %s ==\n' "$*"; }

step "cargo fmt --check"
cargo fmt --check

step "cargo doc --no-deps (RUSTDOCFLAGS=-D warnings)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

if [ "${1:-all}" = "docs" ]; then
    echo "docs gate OK"
    exit 0
fi

step "cargo build --release"
cargo build --release

# Invariant analyzer, hard gate: INV-ALLOC / INV-DET / INV-PANIC /
# INV-SAFETY / INV-WIRE over rust/src/ (see DESIGN.md §Static analysis
# & invariants). Nonzero exit on any finding.
step "qadam lint (invariant analyzer)"
LINT_OUT="$(target/release/qadam lint --root .)"
echo "$LINT_OUT"
# Waiver budget, pinned: exactly the one pre-existing INV-DET waiver
# (the TCP straggler deadline). The obs clock seam lives outside the
# INV-DET scope precisely so tracing adds no new waivers — a second
# waiver appearing here is a regression, not a formality.
echo "$LINT_OUT" | grep -q ' 1 waivers, 0 findings'

step "cargo clippy --all-targets (-D warnings)"
cargo clippy --all-targets --quiet -- -D warnings

step "cargo build --release --examples"
cargo build --release --examples

step "cargo test -q"
cargo test -q

# Golden wire-format fixtures run in BOTH debug and --release: the
# fixtures are byte-exact, so an optimization-dependent divergence in a
# codec's float path (fast-math, UB) shows up as a release-only
# mismatch here instead of a silent cross-build wire break.
step "golden wire fixtures (debug)"
cargo test -q --test wire_golden

step "golden wire fixtures (--release)"
cargo test -q --release --test wire_golden

# The sparse-codec conservation wall runs in both builds for the same
# reason: the per-coordinate `q + e == u` properties are bit-exact, so
# an optimization-dependent float divergence in the top-k selection or
# scale math would surface as a release-only failure here.
step "sparse codec conservation suite (debug)"
cargo test -q --test sparse_codec

step "sparse codec conservation suite (--release)"
cargo test -q --release --test sparse_codec

# Smoke-run the examples so example rot fails CI, not a user's first
# ten minutes. fedlearn_edge needs no artifacts (sim problem over real
# TCP, lossy chaos plan on); quickstart needs the PJRT artifacts and is
# skipped when they are absent.
step "example smoke: fedlearn_edge (lossy chaos, tiny budget)"
cargo run --release --example fedlearn_edge -- --devices 2 --steps 40 --dim 512

# The client-sampling walkthrough: 10k/100k/1M logical-worker
# registries at the same cohort size must cost the same per round (the
# example itself fails past a 3x spread).
step "example smoke: federated_cohort (sampled cohorts, flat cost)"
cargo run --release --example federated_cohort

# The MoE sparse-codec walkthrough at a tiny size: sparse policies on
# both directions end to end, with the example's own assertions (sparse
# runs train; topk undercuts dense bytes in both directions at equal
# rounds; adaptive densities stay in band).
# (expert-dim stays >= 128 here: below that, the per-part codec
# headers dominate the sparse payloads and the example's
# bytes-undercut assertion is no longer the regime it documents.)
step "example smoke: moe_sparse (sparse codecs + EF, tiny MoE)"
cargo run --release --example moe_sparse -- --experts 4 --expert-dim 128 \
    --rounds 20 --workers 2

# One-round smoke of the codec-policy sweep: catches bench rot and the
# adaptive plumbing (parts frames end to end) without paying for the
# full equal-budget comparison.
step "bench smoke: policy_sweep (1 round)"
cargo bench --bench policy_sweep -- --rounds 1 --dim 4096 --workers 2

# One-round smoke of the shard-scaling sweep: the sharded server +
# threaded lanes end to end, plus the machine-readable JSON emitter.
step "bench smoke: shard_scaling (1 round)"
cargo bench --bench shard_scaling -- --rounds 1 --dim 4096 --workers 2 --shards 1,2 \
    --json /tmp/BENCH_shard_scaling_smoke.json
grep -q '"bench": "shard_scaling"' /tmp/BENCH_shard_scaling_smoke.json

# Hot-path bench trajectory, smoke-sized: both emitters run at tiny
# sizes, the fresh quant_micro JSON is self-compared through `qadam
# bench-diff` (the regression math must hold at 0% diff), and the
# committed BENCH_*.json baselines must stay parseable (null medians
# are legal placeholders). The full-size gate is scripts/bench_diff.sh.
step "bench smoke: quant_micro + worker_step + bench-diff"
cargo bench --bench quant_micro -- --sizes 4096 --target-ms 20 \
    --json /tmp/BENCH_quant_micro_smoke.json
grep -q '"bench": "quant_micro"' /tmp/BENCH_quant_micro_smoke.json
cargo bench --bench worker_step -- --dim 4096 --workers 1,2 --step-dims 4096 \
    --target-ms 20 --downlink-rounds 4 --skip-pjrt \
    --json /tmp/BENCH_worker_step_smoke.json
grep -q '"bench": "worker_step"' /tmp/BENCH_worker_step_smoke.json
target/release/qadam bench-diff --baseline /tmp/BENCH_quant_micro_smoke.json \
    --fresh /tmp/BENCH_quant_micro_smoke.json
target/release/qadam bench-diff --baseline BENCH_quant_micro.json \
    --fresh /tmp/BENCH_quant_micro_smoke.json
target/release/qadam bench-diff --baseline BENCH_worker_step.json \
    --fresh /tmp/BENCH_worker_step_smoke.json

# Equal-budget sparse-vs-dense sweep, smoke-sized: the MoE workload +
# sparse policy rows end to end, the JSON emitter, and the bench-diff
# math over its entries (self-compare must hold at 0% diff).
step "bench smoke: sparse_sweep (2 rounds) + bench-diff self-compare"
cargo bench --bench sparse_sweep -- --rounds 2 --experts 4 --expert-dim 64 \
    --workers 2 --json /tmp/BENCH_sparse_sweep_smoke.json
grep -q '"bench": "sparse_sweep"' /tmp/BENCH_sparse_sweep_smoke.json
target/release/qadam bench-diff --baseline /tmp/BENCH_sparse_sweep_smoke.json \
    --fresh /tmp/BENCH_sparse_sweep_smoke.json

# Binary-compatibility probe: `qadam info` must print its capability
# JSON (wire version, frame tags, codecs, shard conventions, invariant
# registry) without needing artifacts.
step "cli smoke: qadam info"
INFO_JSON="$(target/release/qadam info)"
echo "$INFO_JSON" | grep -q '"wire_version"'
echo "$INFO_JSON" | grep -q '"invariant_registry"'
# the obs capability set: exporters, trace schema, metric names
echo "$INFO_JSON" | grep -q '"obs"'
echo "$INFO_JSON" | grep -q '"trace_schema_version": 1'
echo "$INFO_JSON" | grep -q 'qadam_rounds_total'
# the sparse codec family: ids in the frame-tag registry, names in the
# codec list
echo "$INFO_JSON" | grep -q '"codec_ids"'
echo "$INFO_JSON" | grep -q '"sparse_block"'

# The README operator runbook, executed as written: two shard servers
# (one listener each, base port + shard id), two workers fanning their
# per-shard frames across both. Everything must exit cleanly.
step "2-shard TCP smoke (README runbook)"
target/release/qadam serve --addr 127.0.0.1:17841 --shard-id 0/2 --workers 2 \
    --dim 64 --steps 5 --kg 2 --downlink delta &
S0=$!
target/release/qadam serve --addr 127.0.0.1:17841 --shard-id 1/2 --workers 2 \
    --dim 64 --steps 5 --kg 2 --downlink delta &
S1=$!
target/release/qadam worker --addr 127.0.0.1:17841 --shards 2 --id 0 \
    --dim 64 --kg 2 --downlink delta &
W0=$!
target/release/qadam worker --addr 127.0.0.1:17841 --shards 2 --id 1 \
    --dim 64 --kg 2 --downlink delta
wait "$S0"
wait "$S1"
wait "$W0"

# Observability smoke, transport half (no artifacts needed): a serve
# process with --metrics-addr and --trace-out. The metrics listener
# binds before the worker accept loop, so the scrape below runs while
# the fleet is still assembling — proving the endpoint is independent
# of training progress (and of the worker port, which would treat the
# scraper as a rejoining worker).
step "obs smoke: serve --metrics-addr + --trace-out + scrape"
rm -f /tmp/qadam_serve_trace.jsonl
target/release/qadam serve --addr 127.0.0.1:17901 --workers 2 --dim 64 --steps 5 \
    --kg 2 --metrics-addr 127.0.0.1:17911 --trace-out /tmp/qadam_serve_trace.jsonl &
SRV=$!
METRICS=""
for _ in $(seq 1 50); do
    if METRICS="$( (exec 3<>/dev/tcp/127.0.0.1/17911 \
            && printf 'GET /metrics HTTP/1.0\r\n\r\n' >&3 && cat <&3) 2>/dev/null)" \
        && [ -n "$METRICS" ]; then
        break
    fi
    sleep 0.1
done
echo "$METRICS" | grep -q '200 OK'
echo "$METRICS" | grep -q 'text/plain; version=0.0.4'
echo "$METRICS" | grep -q '^qadam_rounds_total'
echo "$METRICS" | grep -q 'qadam_round_latency_ms_bucket'
target/release/qadam worker --addr 127.0.0.1:17901 --id 0 --dim 64 --kg 2 &
W0=$!
target/release/qadam worker --addr 127.0.0.1:17901 --id 1 --dim 64 --kg 2
wait "$W0"
wait "$SRV"
# The serve trace: schema header plus real per-shard spans. (A serve
# process never requantizes — no eval view — so the full-lifecycle
# check below runs on the traced train instead.)
head -1 /tmp/qadam_serve_trace.jsonl | grep -q '"trace_schema_version": 1'
grep -q '"span": "broadcast"' /tmp/qadam_serve_trace.jsonl
grep -q '"span": "gather"' /tmp/qadam_serve_trace.jsonl
grep -q '"span": "decode_apply"' /tmp/qadam_serve_trace.jsonl
target/release/qadam top --trace /tmp/qadam_serve_trace.jsonl --once | grep -q 'bcast_ms'

# Async bounded-staleness smoke (no artifacts): a serve process in
# --async-rounds mode gathers without a barrier and exports the
# staleness histogram + rejected counter. The round deadline gives each
# gather a real window, so on a quiet loopback the fleet stays fresh
# and the run drains cleanly. The scrape runs while the fleet is still
# assembling — all series exist from the first render, counts and all.
step "async smoke: serve --async-rounds + staleness metrics scrape"
target/release/qadam serve --addr 127.0.0.1:17921 --workers 2 --dim 64 --steps 5 \
    --kg 2 --async-rounds --staleness 2 --round-deadline-ms 500 \
    --metrics-addr 127.0.0.1:17931 &
SRV=$!
METRICS=""
for _ in $(seq 1 50); do
    if METRICS="$( (exec 3<>/dev/tcp/127.0.0.1/17931 \
            && printf 'GET /metrics HTTP/1.0\r\n\r\n' >&3 && cat <&3) 2>/dev/null)" \
        && [ -n "$METRICS" ]; then
        break
    fi
    sleep 0.1
done
echo "$METRICS" | grep -q '200 OK'
echo "$METRICS" | grep -q 'qadam_staleness_rounds_bucket{le="0"}'
echo "$METRICS" | grep -q '^qadam_stale_rejected_total'
target/release/qadam worker --addr 127.0.0.1:17921 --id 0 --dim 64 --kg 2 &
W0=$!
target/release/qadam worker --addr 127.0.0.1:17921 --id 1 --dim 64 --kg 2
wait "$W0"
wait "$SRV"

if [ -f "${QADAM_ARTIFACTS:-artifacts}/manifest.json" ]; then
    # Observability smoke, trainer half: a traced 2-shard LocalBus
    # train must write a lifecycle-covering JSONL trace (`top --check`
    # fails otherwise) and fill the CSV round_ms column on merged rows.
    step "obs smoke: traced 2-shard train + top --check + round_ms CSV"
    target/release/qadam train --model mlp --dataset vector --steps 20 --workers 2 \
        --shards 2 --kg 2 --eval-every 10 \
        --trace-out /tmp/qadam_train_trace.jsonl --csv /tmp/qadam_train_metrics.csv
    target/release/qadam top --trace /tmp/qadam_train_trace.jsonl --check
    head -1 /tmp/qadam_train_metrics.csv | grep -q ',shard,round_ms,staleness_p50,cohort$'
    awk -F, 'NR > 1 && $(NF-3) == -1 && $(NF-2) + 0 > 0 { found = 1 } END { exit !found }' \
        /tmp/qadam_train_metrics.csv

    # Async + cohort trainer smoke: a sampled-cohort bounded-staleness
    # train must fill the trailing staleness_p50/cohort CSV pair — the
    # in-process bus keeps every delta fresh, so merged rows carry
    # p50 = 0 and the cohort size K, not the -1 sync sentinels.
    step "async smoke: train --async-rounds --cohort + staleness CSV columns"
    target/release/qadam train --model mlp --dataset vector --steps 12 --workers 2 \
        --async-rounds --staleness 2 --cohort 4 --registry 100000 --kg 2 \
        --eval-every 6 --csv /tmp/qadam_async_metrics.csv
    awk -F, 'NR > 1 && $(NF-1) + 0 == 0 && $NF + 0 == 4 { found = 1 } END { exit !found }' \
        /tmp/qadam_async_metrics.csv

    step "example smoke: quickstart"
    cargo run --release --example quickstart
else
    step "obs + quickstart smoke (skipped: no artifacts)"
fi

# Opt-in sanitizer lanes (QADAM_SANITIZERS=1): Miri over the bit-packing
# core and ThreadSanitizer over the threaded shard-parity suite — the
# dynamic complement of the INV-SAFETY audit in runtime/mod.rs (the
# TSan lane exercises exactly the `ThreadedBus` cross-thread path the
# `unsafe impl Send/Sync` argument covers). Both need a nightly
# toolchain; each lane auto-skips with a visible notice when its
# toolchain is missing, so the default CI run never depends on rustup
# or nightly being installed.
have_nightly_with() { # component name, e.g. miri / rust-src
    command -v rustup >/dev/null 2>&1 \
        && rustup toolchain list 2>/dev/null | grep -q nightly \
        && rustup component list --toolchain nightly 2>/dev/null \
            | grep "$1" | grep -q installed
}
if [ "${QADAM_SANITIZERS:-0}" = "1" ]; then
    if have_nightly_with miri; then
        step "miri: quant::pack unit tests + pack_fuzz"
        cargo +nightly miri test -q --lib quant::pack
        cargo +nightly miri test -q --test pack_fuzz
    else
        step "miri (SKIPPED: no nightly toolchain with the miri component)"
    fi
    if have_nightly_with rust-src; then
        step "thread sanitizer: shard_parity (ThreadedBus cross-thread path)"
        TSAN_TARGET="$(rustc -vV | sed -n 's/^host: //p')"
        RUSTFLAGS="-Zsanitizer=thread" cargo +nightly test -Zbuild-std \
            --target "$TSAN_TARGET" -q --test shard_parity
    else
        step "thread sanitizer (SKIPPED: no nightly toolchain with the rust-src component)"
    fi
else
    step "sanitizer lanes (SKIPPED: opt-in — set QADAM_SANITIZERS=1; needs nightly + miri/rust-src)"
fi

echo
echo "ci OK"
